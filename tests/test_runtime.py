"""The unified ClusterRuntime request-lifecycle API (paper §5.2, Fig 12):
workload protocol conformance, real concurrency gating, energy accounting
against the ClusterSpec power model, and the deprecation shims."""
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec, UnitSpec, soc_cluster
from repro.core.scheduler import diurnal_trace
from repro.runtime import (ClusterRuntime, DLServingWorkload,
                           LMServingWorkload, QueueWorkload, Request,
                           Response, ScalePolicy, StepStats, Telemetry,
                           TranscodingWorkload, Workload)
from repro.workloads.transcoding import VIDEOS


def tiny_cluster(n_units: int = 8) -> ClusterSpec:
    return ClusterSpec(
        name="tiny",
        unit=UnitSpec("u", p_off=0.0, p_idle=1.0, p_peak=10.0, gamma=1.0),
        n_units=n_units, p_shared=5.0)


@pytest.fixture(scope="module")
def lm_workload_factory():
    from repro.config import ServeConfig, get_config, smoke_config
    from repro.serving.engine import ServingEngine
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    eng = ServingEngine(cfg, ServeConfig(max_seq_len=64))
    eng.init_random(0)

    def make(slots=4, **kw):
        return LMServingWorkload(eng, slots=slots, **kw)

    return make


# ---------------------------------------------------------------------------
# Workload protocol conformance (all three adapters).
# ---------------------------------------------------------------------------
def _conformance(wl, payload, cost=1.0):
    assert isinstance(wl, Workload)
    rid = wl.submit(Request(payload=payload, cost=cost, arrival_s=0.0))
    assert isinstance(rid, int)
    stats = wl.step(4, 1.0, 0.0)
    assert isinstance(stats, StepStats)
    desc = wl.describe()
    assert isinstance(desc, dict) and "name" in desc and "kind" in desc
    for _ in range(200):
        if wl.step(4, 1.0).queued == 0 and wl.step(4, 1.0).concurrency == 0:
            break
    out = wl.drain()
    assert isinstance(out, list)
    assert all(isinstance(r, Response) for r in out)
    assert any(r.rid == rid for r in out)


def test_protocol_dl_serving():
    _conformance(DLServingWorkload(unit_rate=2.0), payload=None, cost=3.0)


def test_protocol_transcoding():
    _conformance(TranscodingWorkload(VIDEOS[0]), payload=None, cost=5.0)


def test_protocol_lm_serving(lm_workload_factory):
    wl = lm_workload_factory(slots=2, max_new_tokens=4)
    prompt = np.ones(6, np.int32)
    _conformance(wl, payload=prompt)


def test_dl_serving_from_point_rate():
    wl = DLServingWorkload.from_point("resnet-50", "fp32", "soc-gpu")
    # Table 7: 32.5 ms batch-1 -> ~30.8 samples/s per SoC
    assert wl.unit_rate == pytest.approx(1000.0 / 32.5)
    assert wl.describe()["platform"] == "soc-gpu"


def test_transcoding_capacity_is_table3_streams():
    v = VIDEOS[0]                       # V1: 13 cpu / 16 hw streams per SoC
    assert TranscodingWorkload(v).unit_rate == v.soc_cpu_streams
    assert TranscodingWorkload(v, hw_codec=True).unit_rate == \
        v.soc_hw_streams


# ---------------------------------------------------------------------------
# Gating actually limits concurrency (the seed repo's dead-code fix).
# ---------------------------------------------------------------------------
def test_batcher_max_slots_caps_admission(lm_workload_factory):
    wl = lm_workload_factory(slots=4, max_new_tokens=3)
    bat = wl.batcher
    for _ in range(6):
        bat.submit(np.ones(4, np.int32), max_new_tokens=3)
    live = bat.step(max_slots=2)
    assert live == 2
    assert sum(a is not None for a in bat.active) <= 2
    # uncapped step uses all slots
    live = bat.step()
    assert live == 4


def test_runtime_gates_lm_concurrency(lm_workload_factory):
    wl = lm_workload_factory(slots=4, max_new_tokens=3)
    for _ in range(8):
        wl.submit(Request(payload=np.ones(4, np.int32)))
    # one active unit x one slot/unit -> at most 1 in flight per tick
    seen = []
    for _ in range(40):
        stats = wl.step(1, 1.0)
        seen.append(stats.concurrency)
        if stats.queued == 0 and stats.concurrency == 0:
            break
    assert max(seen) == 1
    assert sum(s.rid is not None for s in wl.drain()) == 8


def test_queue_workload_capacity_gated():
    wl = QueueWorkload(unit_rate=2.0)
    wl.submit(Request(cost=100.0))
    stats = wl.step(3, 1.0)             # 3 units x 2/s x 1s = 6 done
    assert stats.work_done == pytest.approx(6.0)
    stats = wl.step(0, 1.0)             # fully gated: nothing moves
    assert stats.work_done == 0.0
    assert wl.pending_cost == pytest.approx(94.0)


def test_run_to_completion_returns_finished(lm_workload_factory):
    bat = lm_workload_factory(slots=2).batcher
    rids = [bat.submit(np.ones(4, np.int32), max_new_tokens=3)
            for _ in range(3)]
    done = bat.run_to_completion()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert all(len(r.generated) == 3 for r in done)


# ---------------------------------------------------------------------------
# Telemetry energy must match the ClusterSpec power integration.
# ---------------------------------------------------------------------------
def test_energy_matches_power_model():
    spec = tiny_cluster(8)
    wl = QueueWorkload(unit_rate=1.0)
    rt = ClusterRuntime(spec, wl, policy=ScalePolicy(min_units=2),
                        dt_s=1.0)
    for _ in range(5):
        rt.submit(cost=2.0, count=2.0)
        rt.tick()
    tel = rt.telemetry()
    expected = sum(
        spec.power(int(a), float(u), idle_units_off=True) * 1.0
        for a, u in zip(tel.active_units, tel.utilization))
    assert tel.energy_j == pytest.approx(expected)
    # and each recorded power sample is the model's value exactly
    for a, u, p in zip(tel.active_units, tel.utilization, tel.power_w):
        assert p == pytest.approx(
            spec.power(int(a), float(u), idle_units_off=True))


def test_acceptance_diurnal_gating_tracks_load_and_saves_energy():
    """Acceptance: under a diurnal trace the mean activation tracks the
    offered load within the policy headroom, and gated energy beats the
    static all-units-on baseline."""
    spec = soc_cluster()
    unit_rate = 10.0
    wl = QueueWorkload(unit_rate=unit_rate)
    rt = ClusterRuntime(spec, wl, policy=ScalePolicy(cooldown_s=120.0))
    trace = diurnal_trace(peak_rps=unit_rate * spec.n_units * 0.8,
                          hours=24, dt_s=60.0)
    tel = rt.play_trace(trace, dt_s=60.0)
    ideal = np.minimum(
        spec.n_units,
        np.maximum(1, np.ceil(trace * 1.25 / unit_rate))).mean()
    assert tel.mean_active == pytest.approx(ideal, rel=0.15)
    assert tel.energy_j < rt.static_baseline_energy()
    assert tel.served == pytest.approx(float((trace * 60.0).sum()),
                                       rel=1e-6)
    # activation trace correlates with the offered load trace
    corr = np.corrcoef(tel.offered_load, tel.active_units)[0, 1]
    assert corr > 0.95


def test_scale_down_keeps_inflight_powered(lm_workload_factory):
    """In-flight slots outliving a scale-down stay powered and charged."""
    wl = lm_workload_factory(slots=4, max_new_tokens=6)
    spec = tiny_cluster(4)
    rt = ClusterRuntime(spec, wl, policy=ScalePolicy(min_units=4,
                                                     cooldown_s=0.0),
                        unit_rate=1.0)
    for _ in range(4):
        rt.submit(np.ones(4, np.int32))
    stats = rt.tick()
    assert stats.concurrency == 4
    # force the governor target down; in-flight work keeps its units
    rt.governor.active_units = 1
    rt.governor.policy.min_units = 1
    stats = rt.tick()
    assert stats.concurrency == 4
    assert stats.active_units == 4          # powered for the overflow
    assert stats.power_w == pytest.approx(
        spec.power(4, stats.utilization, idle_units_off=True))


def test_group_units_activates_whole_groups():
    spec = soc_cluster()                        # 60 units, 5 per PCB
    rt = ClusterRuntime(spec, QueueWorkload(unit_rate=1.0),
                        policy=ScalePolicy(cooldown_s=0.0),
                        group_units=5)
    gov = rt.governor
    # need 7 units -> 2 whole groups of 5
    assert gov.target_units(7.0 / gov.policy.headroom) == 10
    assert gov.target_units(0.0) == 5           # floor is one group
    assert gov.target_units(1e9) == 60          # cap at whole groups


def test_hedge_after_s_accepted_silently():
    """hedge_after_s is honored by the runtime now — the old 'ignored'
    RuntimeWarning must be gone."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ClusterRuntime(tiny_cluster(4), QueueWorkload(unit_rate=1.0),
                       policy=ScalePolicy(hedge_after_s=1.0))


# ---------------------------------------------------------------------------
# Runtime-level straggler hedging (paper §5.2).
# ---------------------------------------------------------------------------
def test_runtime_hedging_borrows_and_charges():
    """A request stuck past hedge_after_s borrows one free unit for the
    tick, and the borrowed unit's energy is charged."""
    spec = tiny_cluster(4)
    wl = QueueWorkload(unit_rate=1.0)
    rt = ClusterRuntime(spec, wl,
                        policy=ScalePolicy(min_units=1, cooldown_s=1e9,
                                           hedge_after_s=2.0))
    rt.submit(cost=10.0)                  # arrives at t=0, saturates 1 unit
    assert rt.tick().hedge_units == 0     # t=0: age 0
    rt.tick()                             # t=1
    rt.tick()                             # t=2: age 2, not > 2
    stats = rt.tick()                     # t=3: age 3 > 2 -> hedge
    assert stats.hedge_units == 1
    assert stats.active_units == 2        # 1 granted + 1 borrowed
    assert stats.power_w == pytest.approx(
        spec.power(2, stats.utilization, idle_units_off=True))
    tel = rt.telemetry()
    assert tel.hedged >= 1


def test_hedged_run_completes_sooner_and_cheaper_tail():
    def run_one(hedge):
        wl = QueueWorkload(unit_rate=1.0)
        rt = ClusterRuntime(
            tiny_cluster(8), wl,
            policy=ScalePolicy(min_units=1, cooldown_s=1e9,
                               hedge_after_s=2.0 if hedge else None))
        rt.submit(cost=12.0)
        return rt.run(max_ticks=200)
    base, hedged = run_one(False), run_one(True)
    assert hedged.hedged > 0 and base.hedged == 0
    assert hedged.p99_latency_s < base.p99_latency_s
    assert max(r.finish_s for r in hedged.responses) < \
        max(r.finish_s for r in base.responses)
    # the borrowed units were powered: mean active is higher while running
    assert hedged.mean_active > 1.0


def test_oldest_waiting_s_queue_workload():
    wl = QueueWorkload(unit_rate=1.0)
    assert wl.oldest_waiting_s(5.0) is None
    wl.submit(Request(cost=3.0, arrival_s=1.0))
    assert wl.oldest_waiting_s(5.0) == pytest.approx(4.0)


def test_oldest_waiting_s_lm_workload(lm_workload_factory):
    wl = lm_workload_factory(slots=2, max_new_tokens=3)
    assert wl.oldest_waiting_s(1.0) is None
    wl.submit(Request(payload=np.ones(4, np.int32), arrival_s=0.0))
    assert wl.oldest_waiting_s(3.0) == pytest.approx(3.0)


def test_no_hedge_when_slot_cap_binds(lm_workload_factory):
    """Borrowing a unit beyond the batcher's slot cap adds no capacity,
    so the runtime must not hedge (or charge) it."""
    wl = lm_workload_factory(slots=2, max_new_tokens=8)
    assert wl.max_useful_units() == 2
    rt = ClusterRuntime(tiny_cluster(8), wl, unit_rate=1.0,
                        policy=ScalePolicy(min_units=2, cooldown_s=1e9,
                                           hedge_after_s=1.0))
    for _ in range(6):
        rt.submit(np.ones(4, np.int32))
    for _ in range(4):
        stats = rt.tick()
        assert stats.hedge_units == 0       # slots already saturated
        assert stats.active_units <= 2
    assert rt.telemetry().hedged == 0


# ---------------------------------------------------------------------------
# Responses reach Telemetry exactly once (drain() is the delivery channel).
# ---------------------------------------------------------------------------
def test_responses_delivered_exactly_once_run():
    wl = QueueWorkload(unit_rate=5.0)
    rt = ClusterRuntime(tiny_cluster(8), wl)
    rids = [rt.submit(cost=1.0) for _ in range(20)]
    tel = rt.run()
    got = [r.rid for r in tel.responses]
    assert sorted(got) == sorted(rids)          # all delivered, no dups
    assert wl.drain() == []                     # nothing left behind


def test_responses_delivered_exactly_once_play_trace():
    wl = QueueWorkload(unit_rate=10.0)
    rt = ClusterRuntime(tiny_cluster(8), wl)
    tel = rt.play_trace(np.full(20, 3.0), dt_s=1.0)
    rids = [r.rid for r in tel.responses]
    assert len(rids) == len(set(rids))
    assert len(rids) == 20                      # one aggregate per tick
    assert wl.drain() == []


# ---------------------------------------------------------------------------
# Group-quantization edge cases.
# ---------------------------------------------------------------------------
def test_quantize_group_not_dividing_cluster():
    from repro.runtime import UnitGovernor
    gov = UnitGovernor(soc_cluster(), 1.0, group_units=7)   # 60 % 7 != 0
    assert gov._quantize(1) == 7                # floor: one whole group
    assert gov._quantize(8) == 14
    assert gov._quantize(58) == 56              # 63 > 60 -> whole groups
    assert gov.target_units(1e9) == 56          # never a partial group
    gov8 = UnitGovernor(tiny_cluster(8), 1.0, group_units=5)
    assert gov8._quantize(6) == 5               # 10 > 8 -> one group of 5
    assert gov8._quantize(2) == 5


def test_quantize_min_units_below_one_group():
    from repro.runtime import UnitGovernor
    gov = UnitGovernor(soc_cluster(), 1.0,
                       policy=ScalePolicy(min_units=2), group_units=5)
    assert gov.target_units(0.0) == 5           # floor rounds up to a group
    assert gov.active_units == 5                # initial activation too


def test_fluid_latency_not_inflated_when_unloaded():
    """An uncongested fluid workload must report sub-tick latency, not
    the tick width."""
    wl = QueueWorkload(unit_rate=10.0)
    rt = ClusterRuntime(tiny_cluster(8), wl)
    tel = rt.play_trace(np.full(50, 4.0), dt_s=60.0)
    assert tel.p99_latency_s < 60.0


# ---------------------------------------------------------------------------
# Deprecation shims.
# ---------------------------------------------------------------------------
def test_simresult_and_report_are_telemetry():
    from repro.core.scheduler import (ElasticScheduler, SimResult,
                                      ScalePolicy as SchedScalePolicy)
    from repro.serving.autoscaler import AutoscalerReport
    assert SimResult is Telemetry
    assert AutoscalerReport is Telemetry
    assert SchedScalePolicy is ScalePolicy
    sched = ElasticScheduler(soc_cluster(), unit_rate=1.0)
    res = sched.simulate(np.full(10, 5.0), dt_s=1.0)
    assert isinstance(res, Telemetry)
    assert res.tpe > 0 and res.ticks == 10
    # the simulator fills every per-tick series of the unified struct
    assert len(res.utilization) == len(res.active_units) == 10


def test_serving_autoscaler_deprecation_and_runtime_roundtrip():
    """The shim must (a) emit DeprecationWarning on construction and
    (b) produce, through the new UnitGovernor/UnitPool path, exactly
    what driving an identical governor directly produces."""
    import warnings
    from repro.runtime import UnitGovernor
    from repro.serving.autoscaler import ServingAutoscaler

    spec = tiny_cluster(8)
    policy = lambda: ScalePolicy(min_units=1, cooldown_s=5.0)  # noqa: E731
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = ServingAutoscaler(spec, unit_rate_rps=2.0, policy=policy(),
                                 window_s=5.0)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    # the shim is a thin veneer: its state lives in the runtime layer
    assert isinstance(shim.governor, UnitGovernor)
    direct = UnitGovernor(spec, 2.0, policy(), window_s=5.0)
    for step in range(30):
        t = float(step)
        n = 6 if 8 <= step < 20 else 1
        shim.record_arrival(t, n)
        direct.record_arrival(t, n)
        shim_active = shim.tick(t, served_this_tick=n)
        active = direct.update(t, 1.0)
        rate = direct.offered_rate(t)
        util = min(1.0, rate / max(active * 2.0, 1e-9))
        direct.charge(t, util, 1.0, served=n)
        assert shim_active == active
    rep, ref = shim.report(), direct.telemetry()
    assert isinstance(rep, Telemetry)
    assert rep.energy_j == pytest.approx(ref.energy_j)
    assert rep.served == pytest.approx(ref.served)
    np.testing.assert_allclose(rep.active_units, ref.active_units)
    np.testing.assert_allclose(rep.power_w, ref.power_w)


def test_serving_autoscaler_shim_still_works():
    from repro.serving.autoscaler import ServingAutoscaler
    with pytest.deprecated_call():
        sc = ServingAutoscaler(tiny_cluster(8), unit_rate_rps=2.0,
                               policy=ScalePolicy(min_units=1,
                                                  cooldown_s=5.0),
                               window_s=5.0)
    for step in range(40):
        t = float(step)
        n = 8 if 10 <= step < 25 else 1
        sc.record_arrival(t, n)
        active = sc.tick(t, served_this_tick=n)
        assert active >= 1
    rep = sc.report()
    assert isinstance(rep, Telemetry)
    assert rep.scale_events >= 2
    assert rep.energy_j > 0
    assert 1.0 < rep.mean_active < 8.0
