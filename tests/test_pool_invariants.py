"""Property test: randomized UnitPool op sequences with the sanitizer
armed — the vector backend's count caches must match the bincount
ground truth after every operation, and the two backends must agree on
every count query throughout.

Requires hypothesis (installed in CI via requirements-dev.txt); skipped
where unavailable.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import soc_cluster  # noqa: E402
from repro.power.opp import sd865_opp_table  # noqa: E402
from repro.runtime import make_unit_pool  # noqa: E402
from repro.runtime.sanitize import check_pool  # noqa: E402

TENANTS = ("a", "b", "c")

# one pool operation: (op name, tenant, k/opp argument)
_op = st.tuples(
    st.sampled_from(("wake", "release", "advance", "force_active",
                     "charge", "set_opp")),
    st.sampled_from(TENANTS),
    st.integers(min_value=0, max_value=12),
)


def _apply(pool, t, op, tenant, k):
    if op == "wake":
        pool.wake(tenant, k, ready_t=t + 1.0)
    elif op == "release":
        pool.release(tenant, k)
    elif op == "advance":
        pool.advance(t, 1.0)
    elif op == "force_active":
        pool.force_active(tenant, k)
    elif op == "charge":
        pool.charge(t, 1.0, {m: (k % 11) / 10.0 for m in TENANTS})
    elif op == "set_opp":
        pool.set_opp(tenant, k)


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=40),
       dvfs=st.booleans())
def test_random_op_sequences_keep_caches_exact(ops, dvfs):
    kwargs = dict(opp_table=sd865_opp_table()) if dvfs else {}
    # sanitize=True re-validates the whole pool after every mutating
    # call — any cache drifting from its bincount ground truth raises
    # InvariantViolation right at the op that broke it
    scalar = make_unit_pool(soc_cluster(), backend="scalar",
                            sanitize=True, **kwargs)
    vector = make_unit_pool(soc_cluster(), backend="vector",
                            sanitize=True, **kwargs)
    for i, (op, tenant, k) in enumerate(ops):
        t = float(i)
        _apply(scalar, t, op, tenant, k)
        _apply(vector, t, op, tenant, k)
        # twin engines must agree on every count query
        for m in TENANTS:
            assert scalar.active(m) == vector.active(m), (i, op, m)
            assert scalar.waking(m) == vector.waking(m), (i, op, m)
            assert scalar.owned(m) == vector.owned(m), (i, op, m)
            assert scalar.units_of(m) == vector.units_of(m), (i, op, m)
        assert scalar.n_allocated() == vector.n_allocated()
        assert scalar.n_active() == vector.n_active()
        assert scalar.free_units() == vector.free_units()
    # and a final standalone deep check of both pools
    check_pool(scalar)
    check_pool(vector)
    assert scalar.energy_j == vector.energy_j
