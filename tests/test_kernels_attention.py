"""Pallas flash/decode attention vs the pure-jnp oracle: shape/dtype sweeps
(interpret=True executes the kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention

SHAPES = [
    # (b, sq, hq, hkv, d)
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (2, 128, 4, 1, 128),    # MQA
    (1, 512, 2, 2, 32),     # long-ish
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(shape, dtype, causal, rng):
    b, sq, hq, hkv, d = shape
    q = jnp.asarray(rng.standard_normal((b, sq, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, sq, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, sq, hkv, d)), dtype)
    out_ref = ref.attention_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(32, 64), (128, 32), (64, 128)])
def test_flash_attention_block_shapes(block_q, block_k, rng):
    b, sq, hq, hkv, d = 1, 256, 2, 1, 64
    q = jnp.asarray(rng.standard_normal((b, sq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, hkv, d)), jnp.float32)
    out_ref = ref.attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("skv,hq,hkv,d", [
    (256, 4, 4, 64), (512, 8, 2, 64), (256, 4, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(skv, hq, hkv, d, dtype, rng):
    b = 3
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype)
    length = jnp.asarray(rng.integers(1, skv + 1, size=b), jnp.int32)
    out_ref = ref.decode_attention_ref(q, k, v, length)
    out = decode_attention(q, k, v, length, block_k=128, interpret=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_respects_length(rng):
    """Entries beyond `length` must not influence the output."""
    b, skv, hkv, hq, d = 2, 256, 2, 4, 64
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
    length = jnp.array([100, 200], jnp.int32)
    out1 = decode_attention(q, k, v, length, interpret=True)
    k2 = k.at[:, 200:].set(999.0)
    v2 = v.at[:, 200:].set(-999.0)
    out2 = decode_attention(q, k2, v2, length, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
