"""Multi-tenant unit allocation: UnitPool state machine + group-aligned
placement, weighted-fair arbitration with min_units floors, per-tenant
telemetry, and the single shared power integral (p_shared charged once)."""
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec, UnitSpec, soc_cluster
from repro.core.scheduler import diurnal_trace
from repro.runtime import (ClusterRuntime, MultiTenantRuntime, QueueWorkload,
                           ScalePolicy, Tenant, Telemetry, UnitPool,
                           UnitState, weighted_fair_share)


def tiny_cluster(n_units: int = 8, group_size: int = 1) -> ClusterSpec:
    return ClusterSpec(
        name="tiny",
        unit=UnitSpec("u", p_off=0.0, p_idle=1.0, p_peak=10.0, gamma=1.0),
        n_units=n_units, p_shared=5.0, group_size=group_size)


# ---------------------------------------------------------------------------
# UnitPool state machine.
# ---------------------------------------------------------------------------
def test_pool_wake_release_lifecycle():
    pool = UnitPool(tiny_cluster(4))
    assert pool.free_units() == 4
    assert pool.wake("a", 2, ready_t=3.0) == 2
    assert pool.waking("a") == 2 and pool.active("a") == 0
    pool.advance(0.0, 1.0)                      # ready 3.0 > 1.0: still waking
    assert pool.active("a") == 0
    pool.advance(2.5, 1.0)                      # 3.0 <= 3.5: wakes
    assert pool.active("a") == 2 and pool.waking("a") == 0
    assert pool.release("a", 1) == 1
    assert pool.active("a") == 1 and pool.free_units() == 3


def test_pool_wake_capped_by_free_units():
    pool = UnitPool(tiny_cluster(4))
    pool.force_active("a", 3)
    assert pool.wake("b", 5, ready_t=0.0) == 1  # only one unit left
    pool.advance(0.0, 1.0)
    assert pool.active("b") == 1
    assert pool.free_units() == 0


def test_pool_group_aligned_placement():
    pool = UnitPool(soc_cluster())              # 60 units, 5 per PCB
    pool.wake("a", 7, ready_t=0.0)
    pool.advance(0.0, 1.0)
    groups_a = {u // 5 for u in pool.units_of("a")}
    assert len(groups_a) == 2                   # 7 units span exactly 2 PCBs
    pool.wake("b", 5, ready_t=0.0)
    pool.advance(0.0, 1.0)
    groups_b = {u // 5 for u in pool.units_of("b")}
    assert len(groups_b) == 1                   # whole free PCB
    assert groups_a.isdisjoint(groups_b)
    # growth packs into the tenant's own partial group first
    pool.wake("a", 3, ready_t=0.0)
    pool.advance(0.0, 1.0)
    assert {u // 5 for u in pool.units_of("a")} == groups_a


def test_pool_release_vacates_least_occupied_groups():
    pool = UnitPool(soc_cluster())
    pool.force_active("a", 7)                   # groups: 5 + 2
    pool.release("a", 2)                        # drops the 2-unit straggler
    assert {u // 5 for u in pool.units_of("a")} == {0}
    assert pool.active("a") == 5


def test_pool_charge_matches_spec_power_single_tenant():
    spec = tiny_cluster(8)
    pool = UnitPool(spec, idle_units_off=True)
    pool.force_active("a", 3)
    total, per, powered = pool.charge(0.0, 1.0, {"a": 0.5})
    assert powered["a"] == 3
    assert total == pytest.approx(spec.power(3, 0.5, idle_units_off=True))
    assert per["a"] == pytest.approx(3 * spec.unit.power(0.5))
    assert pool.energy_j == pytest.approx(total)
    assert pool.tenant_energy_j["a"] == pytest.approx(per["a"])


def test_pool_charge_shared_power_once():
    spec = tiny_cluster(8)
    pool = UnitPool(spec, idle_units_off=True)
    pool.force_active("a", 2)
    pool.force_active("b", 3)
    total, per, _ = pool.charge(0.0, 1.0, {"a": 1.0, "b": 0.5})
    expect = spec.p_shared + 2 * spec.unit.power(1.0) \
        + 3 * spec.unit.power(0.5)
    assert total == pytest.approx(expect)       # p_shared exactly once
    assert sum(per.values()) == pytest.approx(expect - spec.p_shared)


def test_pool_state_enum():
    pool = UnitPool(tiny_cluster(2))
    assert pool.state[0] is UnitState.OFF
    pool.wake("a", 1, ready_t=9.0)
    assert pool.state[pool.units_of("a")[0]] is UnitState.WAKING


def test_pool_release_cancels_waking_units_first():
    """A demand drop that lands while units are still waking cancels the
    pending wakes (they are not serving yet) before any active unit is
    powered off."""
    pool = UnitPool(tiny_cluster(6))
    pool.force_active("a", 2)
    pool.wake("a", 2, ready_t=50.0)            # far-future wakes
    assert pool.waking("a") == 2 and pool.active("a") == 2
    assert pool.release("a", 2) == 2
    assert pool.waking("a") == 0               # both wakes cancelled...
    assert pool.active("a") == 2               # ...no active unit touched
    assert pool.free_units() == 4
    # over-release spills from waking into active
    pool.wake("a", 1, ready_t=50.0)
    assert pool.release("a", 2) == 2
    assert pool.waking("a") == 0 and pool.active("a") == 1


def test_pool_force_active_exact_with_waking_units():
    """force_active's 'exactly k active' contract must hold even while
    wakes are in flight: pending wakes are cancelled, actives trimmed."""
    pool = UnitPool(tiny_cluster(8))
    pool.force_active("a", 5)
    pool.wake("a", 2, ready_t=99.0)
    pool.force_active("a", 3)
    assert pool.active("a") == 3 and pool.waking("a") == 0
    pool.wake("a", 2, ready_t=99.0)
    pool.force_active("a", 6)
    assert pool.active("a") == 6 and pool.waking("a") == 0


def test_pool_release_waking_prefers_newest_wake():
    pool = UnitPool(tiny_cluster(4))
    pool.wake("a", 1, ready_t=10.0)
    pool.wake("a", 1, ready_t=99.0)
    assert pool.release("a", 1) == 1
    # the unit furthest from readiness was the one cancelled
    left = [u for u in range(4) if pool.owner[u] == "a"]
    assert len(left) == 1 and pool._ready_t[left[0]] == 10.0


def test_pool_allocation_when_every_group_partially_occupied():
    """With no wholly-free PCB left, growth packs into the tenant's own
    partial groups first, then spills into the least-crowded foreign
    ones — and a newcomer can still claim the leftovers."""
    spec = soc_cluster()                       # 60 units, 5 per PCB
    pool = UnitPool(spec)
    # occupy 3 units in every one of the 12 groups
    for gi, g in enumerate(pool._groups):
        for u in g[:3]:
            pool.state[u] = UnitState.ACTIVE
            pool.owner[u] = f"t{gi}"
    assert pool.free_units() == 24
    # t0 grows by 4: its own group's 2 free slots first, then elsewhere
    assert pool.wake("t0", 4, ready_t=0.0) == 4
    pool.advance(0.0, 1.0)
    by_group = {}
    for u in pool.units_of("t0"):
        by_group[u // 5] = by_group.get(u // 5, 0) + 1
    assert by_group[0] == 5                    # own group filled to the brim
    assert sum(by_group.values()) == 7
    # a newcomer still gets units even though no group is wholly free
    assert pool.wake("new", 3, ready_t=0.0) == 3
    pool.advance(0.0, 1.0)
    assert pool.active("new") == 3


def test_hedging_borrows_when_all_groups_partially_occupied():
    """Straggler hedging needs only a free unit, not a free group."""
    spec = tiny_cluster(6, group_size=3)
    mk = lambda m: QueueWorkload(1.0, name=m)   # noqa: E731
    rt = MultiTenantRuntime(spec, [
        Tenant("a", mk("a"), policy=ScalePolicy(min_units=2, cooldown_s=1e9,
                                                hedge_after_s=2.0)),
        Tenant("b", mk("b"), policy=ScalePolicy(min_units=2,
                                                cooldown_s=1e9)),
    ], dt_s=1.0)
    # both groups are now partially occupied (2 of 3 units each by
    # placement), with 2 free units total
    occupied = {u // 3 for u in rt.pool.units_of("a")} \
        | {u // 3 for u in rt.pool.units_of("b")}
    assert occupied == {0, 1}
    assert rt.pool.free_units() == 2
    rt.submit("a", cost=30.0, count=1.0)       # deep backlog for one unit
    hedged = 0
    for _ in range(6):
        stats = rt.tick_all()
        hedged += stats["a"].hedge_units
    assert hedged > 0                          # borrowed despite no free PCB


# ---------------------------------------------------------------------------
# Weighted fair share arbitration.
# ---------------------------------------------------------------------------
def test_arbitration_no_contention_grants_demand():
    grants = weighted_fair_share({"a": 3, "b": 4}, {"a": 1, "b": 1},
                                 {"a": 1.0, "b": 1.0}, capacity=60)
    assert grants == {"a": 3, "b": 4}


def test_arbitration_weighted_with_floors():
    grants = weighted_fair_share({"a": 10, "b": 10}, {"a": 2, "b": 2},
                                 {"a": 3.0, "b": 1.0}, capacity=8)
    assert sum(grants.values()) == 8
    assert grants == {"a": 5, "b": 3}           # extra 4 split 3:1
    # floors always respected
    assert grants["a"] >= 2 and grants["b"] >= 2


def test_arbitration_grants_whole_groups_only():
    """A tensor-parallel tenant is never handed a partial collaboration
    group under contention."""
    grants = weighted_fair_share({"tp": 10, "solo": 10},
                                 {"tp": 0, "solo": 0},
                                 {"tp": 1.0, "solo": 1.0},
                                 capacity=12, groups={"tp": 5, "solo": 1})
    assert grants["tp"] % 5 == 0 and grants["tp"] > 0
    assert sum(grants.values()) == 12
    # capacity too small for even one group: the TP tenant gets nothing
    grants = weighted_fair_share({"tp": 10}, {"tp": 0}, {"tp": 1.0},
                                 capacity=3, groups={"tp": 5})
    assert grants["tp"] == 0


def test_arbitration_floor_capped_by_demand():
    grants = weighted_fair_share({"a": 1, "b": 10}, {"a": 4, "b": 4},
                                 {"a": 1.0, "b": 1.0}, capacity=6)
    assert grants["a"] == 1                     # never granted beyond demand
    assert grants["b"] == 5


def test_runtime_asserts_floor_overcommit():
    wl = lambda: QueueWorkload(unit_rate=1.0)   # noqa: E731
    with pytest.raises(AssertionError, match="floors"):
        MultiTenantRuntime(tiny_cluster(4), [
            Tenant("a", wl(), policy=ScalePolicy(min_units=3)),
            Tenant("b", wl(), policy=ScalePolicy(min_units=3)),
        ])


# ---------------------------------------------------------------------------
# Colocated runtime: invariants + per-tenant telemetry.
# ---------------------------------------------------------------------------
def _three_tenant_run():
    spec = soc_cluster()
    rates = {"a": 5.0, "b": 8.0, "c": 3.0}
    tenants = [Tenant(m, QueueWorkload(r, name=m),
                      policy=ScalePolicy(cooldown_s=120.0))
               for m, r in rates.items()]
    rt = MultiTenantRuntime(spec, tenants, dt_s=60.0)
    n = 120
    traces = {
        m: np.roll(diurnal_trace(peak_rps=r * spec.n_units * 0.4, hours=2,
                                 dt_s=60.0, seed=i), i * n // 3)
        for i, (m, r) in enumerate(rates.items())}
    tel = rt.play_traces(traces, dt_s=60.0)
    return spec, rt, tel


def test_multi_tenant_capacity_and_energy_invariants():
    spec, rt, tel = _three_tenant_run()
    per = tel.per_tenant
    stacked = np.vstack([per[m].active_units for m in per])
    # sum of per-tenant active units never exceeds the pool, every tick
    assert np.all(stacked.sum(axis=0) <= spec.n_units)
    assert np.array_equal(stacked.sum(axis=0), tel.active_units)
    # cluster energy is the single pool-level power integral
    assert tel.energy_j == pytest.approx(float(np.sum(tel.power_w) * 60.0))
    # per-tick decomposition: total = p_shared (once) + per-tenant + rest
    rest = spec.n_units - tel.active_units
    p_rest = rest * spec.unit.p_off
    tenant_p = np.sum(np.vstack([per[m].power_w for m in per]), axis=0)
    assert np.allclose(tel.power_w, spec.p_shared + tenant_p + p_rest)
    # attributed energy sums below cluster energy (shared not in tenants)
    assert sum(p.energy_j for p in per.values()) < tel.energy_j
    assert tel.unit_energy_j == pytest.approx(
        sum(p.energy_j for p in per.values()))
    # per-tenant served roll up to the cluster count
    assert tel.served == pytest.approx(sum(p.served for p in per.values()))
    for m, p in per.items():
        assert isinstance(p, Telemetry) and p.tenant == m
        assert p.served > 0 and p.energy_j > 0


def test_colocation_cheaper_than_dedicated_clusters():
    spec, rt, tel = _three_tenant_run()
    rates = {"a": 5.0, "b": 8.0, "c": 3.0}
    n = 120
    dedicated = 0.0
    for i, (m, r) in enumerate(rates.items()):
        trace = np.roll(diurnal_trace(peak_rps=r * spec.n_units * 0.4,
                                      hours=2, dt_s=60.0, seed=i),
                        i * n // 3)
        one = ClusterRuntime(soc_cluster(), QueueWorkload(r, name=m),
                             policy=ScalePolicy(cooldown_s=120.0))
        dedicated += one.play_trace(trace, dt_s=60.0).energy_j
    assert tel.energy_j < dedicated             # p_shared charged once


def test_contention_respects_weights_and_floors():
    """Two tenants who each want the whole cluster split it by weight."""
    spec = tiny_cluster(12)
    mk = lambda m: QueueWorkload(1.0, name=m)   # noqa: E731
    rt = MultiTenantRuntime(spec, [
        Tenant("heavy", mk("heavy"), weight=2.0,
               policy=ScalePolicy(min_units=2, cooldown_s=0.0)),
        Tenant("light", mk("light"), weight=1.0,
               policy=ScalePolicy(min_units=2, cooldown_s=0.0)),
    ], dt_s=1.0)
    for _t in range(30):
        rt.submit("heavy", cost=40.0, count=40.0)
        rt.submit("light", cost=40.0, count=40.0)
        stats = rt.tick_all()
        total = sum(s.active_units for s in stats.values())
        assert total <= spec.n_units
    # steady state: demand is 12+ each; weighted shares ~8 vs ~4
    heavy = rt.governor_of("heavy").active_units
    light = rt.governor_of("light").active_units
    assert heavy + light <= spec.n_units
    assert heavy > light >= 2
    assert heavy == pytest.approx(8, abs=1)


def test_single_tenant_facade_unchanged_semantics():
    """ClusterRuntime (one tenant) reports cluster-level power/energy and
    matches a hand-built one-tenant MultiTenantRuntime."""
    spec = tiny_cluster(8)
    trace = np.full(30, 4.0)
    a = ClusterRuntime(spec, QueueWorkload(2.0),
                       policy=ScalePolicy(cooldown_s=5.0))
    tel_a = a.play_trace(trace, dt_s=1.0)
    b = MultiTenantRuntime(
        spec, [Tenant("only", QueueWorkload(2.0),
                      policy=ScalePolicy(cooldown_s=5.0))], dt_s=1.0)
    tel_b = b.play_traces({"only": trace}, dt_s=1.0)
    assert tel_a.energy_j == pytest.approx(tel_b.energy_j)
    assert tel_a.served == pytest.approx(tel_b.served)
    np.testing.assert_allclose(tel_a.power_w, tel_b.power_w)
