"""Runtime invariant sanitizer: clean runs pass untouched, injected
corruption is caught at the mutating call, and the overhead on a
small-config run stays within the tier-1 budget."""
import time

import numpy as np
import pytest

from repro.core.cluster import soc_cluster
from repro.fleet.fleet import Fleet, homogeneous_fleet
from repro.power.opp import sd865_opp_table
from repro.power.thermal import ThermalParams
from repro.runtime import make_unit_pool
from repro.runtime.sanitize import (InvariantViolation, attach_fleet_sanitizer,
                                    attach_pool_sanitizer, check_pool,
                                    resolve_sanitize, sanitizer_enabled)
from repro.runtime.pool import _ACTIVE, _WAKING

BACKENDS = ("scalar", "vector")
TRACE = [50.0, 150.0, 90.0, 0.0, 220.0, 10.0]


def small_pool(backend, thermal=False):
    kwargs = {}
    if thermal:
        kwargs = dict(opp_table=sd865_opp_table(),
                      thermal=ThermalParams())
    return make_unit_pool(soc_cluster(), backend=backend, sanitize=True,
                          **kwargs)


# ---------------------------------------------------------------------------
# clean runs pass


@pytest.mark.parametrize("backend", BACKENDS)
def test_clean_pool_ops_pass(backend):
    pool = small_pool(backend)
    assert pool.wake("a", 5, ready_t=1.0) == 5
    assert pool.advance(2.0, 1.0) == 5
    pool.charge(0.0, 1.0, {"a": 0.7})
    assert pool.release("a", 2) == 2
    pool.force_active("a", 6)
    pool.force_active("a", 1)
    assert pool.active("a") == 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_clean_dvfs_thermal_run_passes(backend):
    pool = small_pool(backend, thermal=True)
    pool.set_opp("a", 99)  # clamped into range
    pool.force_active("a", 8)
    for k in range(20):
        pool.charge(float(k), 1.0, {"a": 1.0})
    assert pool.energy_j > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_clean_fleet_run_passes_and_keeps_parity(backend):
    racks = homogeneous_fleet(soc_cluster(), 3, unit_rate=10.0)
    plain = Fleet(racks, dt_s=1.0, backend=backend,
                  sanitize=False).play_trace(TRACE)
    armed = Fleet(racks, dt_s=1.0, backend=backend,
                  sanitize=True).play_trace(TRACE)
    assert armed.energy_j == plain.energy_j
    assert armed.served == plain.served


def test_env_var_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitizer_enabled()
    assert resolve_sanitize(None) is False
    assert resolve_sanitize(True) is True
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitizer_enabled()
    assert resolve_sanitize(None) is True
    assert resolve_sanitize(False) is False
    pool = make_unit_pool(soc_cluster(), backend="vector")
    assert hasattr(pool, "_sanitizer")


# ---------------------------------------------------------------------------
# injected corruption is caught


def test_count_cache_corruption_caught():
    pool = small_pool("vector")
    pool.wake("a", 4, ready_t=0.0)
    pool.advance(1.0, 1.0)
    pool._n_alloc += 1  # deliberate corruption of the exact cache
    with pytest.raises(InvariantViolation, match="_n_alloc"):
        pool.wake("b", 1, ready_t=2.0)


def test_per_tenant_cache_corruption_caught():
    pool = small_pool("vector")
    pool.force_active("a", 3)
    tid = pool._tenant_ids["a"]
    pool._n_active_of[tid] -= 1
    with pytest.raises(InvariantViolation, match="_n_active_of"):
        pool.charge(0.0, 1.0, {"a": 0.5})


def test_group_cache_corruption_caught():
    pool = small_pool("vector")
    pool.force_active("a", 3)
    pool._free_g[0] += 2
    with pytest.raises(InvariantViolation, match="_free_g"):
        pool.release("a", 1)


def test_stale_active_idx_cache_caught():
    pool = small_pool("vector")
    pool.force_active("a", 3)
    tid = pool._tenant_ids["a"]
    pool._active_units_of("a")  # populate the cache
    pool._active_idx[tid] = pool._active_idx[tid][:-1]  # stale copy
    with pytest.raises(InvariantViolation, match="_active_idx"):
        pool.charge(0.0, 1.0, {"a": 0.5})


def test_illegal_transition_active_to_waking_caught():
    from repro.runtime.sanitize import _owner_ids, _state_codes
    pool = small_pool("vector")
    pool.force_active("a", 2)
    prev_state, prev_owner = _state_codes(pool), _owner_ids(pool)
    u = int(np.nonzero(pool._state == _ACTIVE)[0][0])
    pool._state[u] = _WAKING  # a transition no legal op can make
    with pytest.raises(InvariantViolation, match="illegal state transition"):
        check_pool(pool, prev_state, prev_owner)


def test_owner_change_without_off_caught():
    from repro.runtime.sanitize import _owner_ids, _state_codes
    pool = small_pool("vector")
    pool.force_active("a", 2)
    pool.force_active("b", 2)
    prev_state, prev_owner = _state_codes(pool), _owner_ids(pool)
    ua = int(np.nonzero(pool._owner == pool._tenant_ids["a"])[0][0])
    pool._owner[ua] = pool._tenant_ids["b"]  # steal while active
    with pytest.raises(InvariantViolation, match="owner changed"):
        check_pool(pool, prev_state, prev_owner)


def test_scalar_state_owner_inconsistency_caught():
    from repro.runtime.pool import UnitState
    pool = small_pool("scalar")
    pool.force_active("a", 2)
    pool.state[5] = UnitState.ACTIVE  # active but ownerless
    with pytest.raises(InvariantViolation, match="off iff"):
        pool.charge(0.0, 1.0, {"a": 0.5})


def test_thermal_runaway_caught():
    pool = small_pool("vector", thermal=True)
    pool.force_active("a", 4)
    pool.charge(0.0, 1.0, {"a": 1.0})
    pool.thermal.t_die[0] = 1e6  # runaway temperature
    with pytest.raises(InvariantViolation, match="t_die"):
        pool.charge(1.0, 1.0, {"a": 1.0})


def test_energy_regression_caught():
    pool = small_pool("scalar")
    pool.force_active("a", 2)
    pool.charge(0.0, 1.0, {"a": 0.5})
    pool.energy_j = -1e9  # large enough that one tick cannot recover it
    with pytest.raises(InvariantViolation, match="energy"):
        pool.charge(1.0, 1.0, {"a": 0.5})


@pytest.mark.parametrize("backend", BACKENDS)
def test_fleet_conservation_violation_caught(backend):
    racks = homogeneous_fleet(soc_cluster(), 2, unit_rate=10.0)
    fl = Fleet(racks, dt_s=1.0, backend=backend, sanitize=True)
    fl.play_trace(TRACE[:3])
    # leak request mass: the sanitizer's injected ledger no longer
    # matches served + queued
    fl._sanitizer.injected[0] += 7.0
    with pytest.raises(InvariantViolation, match="conservation"):
        fl.engine.tick(np.zeros(2), 1.0)


def test_attach_is_idempotent():
    pool = small_pool("vector")
    s1 = pool._sanitizer
    assert attach_pool_sanitizer(pool) is s1
    racks = homogeneous_fleet(soc_cluster(), 2, unit_rate=10.0)
    fl = Fleet(racks, dt_s=1.0, backend="vector", sanitize=True)
    assert attach_fleet_sanitizer(fl) is fl._sanitizer


# ---------------------------------------------------------------------------
# overhead


def test_sanitizer_overhead_bounded():
    """On the small tier-1 configs the sanitizer must cost < 2x; assert
    a looser 3x here so a noisy CI box cannot flake the suite."""
    racks = homogeneous_fleet(soc_cluster(), 4, unit_rate=10.0,
                              opp_table=sd865_opp_table(),
                              thermal=ThermalParams())
    trace = [60.0 + 40.0 * np.sin(i / 5.0) for i in range(120)]

    def run(sanitize):
        t0 = time.perf_counter()
        Fleet(racks, dt_s=1.0, backend="vector",
              sanitize=sanitize).play_trace(trace)
        return time.perf_counter() - t0

    run(False)  # warm-up
    plain = min(run(False) for _ in range(3))
    armed = min(run(True) for _ in range(3))
    assert armed < 3.0 * max(plain, 1e-3), \
        f"sanitizer overhead {armed / plain:.2f}x exceeds budget"
