"""repro.power — OPP tables, the RC thermal network with trip-point
throttling, frequency governors, and their integration through
UnitPool / UnitGovernor / the runtimes. Also the energy-model parity
check between core.energy.cluster_power_at_load and UnitPool.charge."""
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec, UnitSpec, soc_cluster
from repro.core.energy import (cluster_power_at_load, dvfs_power_at_load,
                               dvfs_proportionality_index,
                               proportionality_index)
from repro.power import (FixedFreqGovernor, FreqContext, FreqGovernor,
                         OperatingPoint, OPPTable, RaceToIdleGovernor,
                         SchedutilGovernor, ThermalAwareGovernor,
                         ThermalModel, ThermalParams, opp_table_for_unit,
                         sd865_opp_table, single_opp_table, unit_power)
from repro.runtime import (ClusterRuntime, QueueWorkload, ScalePolicy,
                           UnitPool)


def tiny_cluster(n_units: int = 8, group_size: int = 1) -> ClusterSpec:
    return ClusterSpec(
        name="tiny",
        unit=UnitSpec("u", p_off=0.0, p_idle=1.0, p_peak=10.0, gamma=1.0),
        n_units=n_units, p_shared=5.0, group_size=group_size)


def _ctx(rate: float, table: OPPTable, spec: ClusterSpec,
         unit_rate: float = 10.0, **kw) -> FreqContext:
    return FreqContext(demand_rate=rate, unit_rate=unit_rate,
                       headroom=1.25, n_units=spec.n_units,
                       table=table, unit=spec.unit, **kw)


# ---------------------------------------------------------------------------
# OPP tables.
# ---------------------------------------------------------------------------
def test_sd865_table_shape_and_nominal():
    t = sd865_opp_table()
    assert len(t) == 5 and t.nominal == t.highest
    freqs = [p.freq_mhz for p in t]
    assert freqs == sorted(freqs)
    nom = t[t.nominal]
    assert nom.perf_scale == 1.0 and nom.power_scale == 1.0
    # every lower point: slower, but super-linearly cheaper (f·V² < f)
    for p in list(t)[:-1]:
        assert p.perf_scale < 1.0
        assert p.power_scale < p.perf_scale


def test_unit_power_nominal_matches_unitspec():
    unit = soc_cluster().unit
    nom = sd865_opp_table()[sd865_opp_table().nominal]
    for u in (0.0, 0.3, 0.7, 1.0):
        assert unit_power(unit, u, nom) == unit.power(u)


def test_generic_builder_from_unitspec():
    unit = tiny_cluster().unit
    t = opp_table_for_unit(unit, n_points=4)
    assert len(t) == 4 and t.nominal == t.highest
    assert t[t.highest].perf_scale == 1.0
    assert t[t.lowest].perf_scale == pytest.approx(0.4)
    # power at the top point reproduces the calibrated wattage exactly
    assert unit_power(unit, 1.0, t[t.highest]) == unit.power(1.0)
    assert unit_power(unit, 1.0, t[t.lowest]) < unit.power(1.0)


def test_table_validates_nominal_scales():
    # the builder normalizes to the nominal point, so an invalid table
    # can only come from direct construction
    with pytest.raises(AssertionError, match="nominal"):
        OPPTable(points=(OperatingPoint(100.0, 0.7, 0.5, 0.3),
                         OperatingPoint(200.0, 1.43, 2.0, 4.1)),
                 nominal=0)


# ---------------------------------------------------------------------------
# Thermal network.
# ---------------------------------------------------------------------------
def test_thermal_heats_toward_steady_state_and_cools():
    spec = tiny_cluster(4, group_size=2)
    tm = ThermalModel(spec, ThermalParams())
    p = [8.0] * 4
    for _ in range(4000):        # » the ~8 min PCB time constant
        tm.step(1.0, p)
    ss = tm.steady_die_temp_c(8.0, units_in_group=2,
                              fan_frac=tm.fan_frac)
    assert tm.t_die[0] == pytest.approx(ss, abs=1.0)
    for _ in range(2000):
        tm.step(1.0, [0.0] * 4)
    assert tm.t_die[0] == pytest.approx(tm.params.t_ambient_c, abs=1.0)


def test_thermal_trip_latch_hysteresis():
    spec = tiny_cluster(1, group_size=1)
    tm = ThermalModel(spec, ThermalParams(t_trip_c=60.0, t_release_c=50.0))
    while not tm.throttled[0]:
        tm.step(1.0, [20.0])
    assert tm.t_die[0] >= 60.0
    # stays latched until it cools below release, not trip
    tm.step(1.0, [0.0])
    assert tm.throttled[0]
    while tm.throttled[0]:
        tm.step(1.0, [0.0])
    assert tm.t_die[0] <= 50.0


def test_thermal_fan_curve_reduces_resistance_and_draws_power():
    spec = tiny_cluster(1)
    tm = ThermalModel(spec, ThermalParams())
    assert tm.r_pcb_eff(0.0) == tm.params.r_pcb_c_per_w
    assert tm.r_pcb_eff(1.0) == pytest.approx(
        tm.params.r_pcb_c_per_w * tm.params.fan_r_scale_min)
    for _ in range(4000):
        fan_w = tm.step(1.0, [20.0])
    assert fan_w > 0.0


def test_sd865_max_sustainable_is_mid_table():
    spec = soc_cluster()
    tm = ThermalModel(spec, ThermalParams())
    t = sd865_opp_table()
    idx = tm.max_sustainable_index(spec.unit, t)
    # the top of the table must NOT be sustainable in the 2U envelope
    # (otherwise the throttling benchmark is vacuous), but something
    # above the floor must be
    assert t.lowest < idx < t.highest


# ---------------------------------------------------------------------------
# Frequency governors.
# ---------------------------------------------------------------------------
def test_fixed_and_race_to_idle():
    spec, t = soc_cluster(), sd865_opp_table()
    assert FixedFreqGovernor().select(_ctx(5.0, t, spec)) == t.highest
    assert FixedFreqGovernor(1).select(_ctx(5.0, t, spec)) == 1
    rti = RaceToIdleGovernor()
    assert rti.select(_ctx(5.0, t, spec)) == t.highest
    assert rti.select(_ctx(0.0, t, spec, backlog=True)) == t.highest
    assert rti.select(_ctx(0.0, t, spec)) == t.nominal


def test_schedutil_prefers_wide_and_slow_at_light_load():
    """At light load on the SD865 table (tiny idle floor, f·V² dynamic
    cost) the cheapest way to meet demand is more units at a lower OPP."""
    spec, t = soc_cluster(), sd865_opp_table()
    idx = SchedutilGovernor().select(_ctx(0.3 * 10.0 * spec.n_units,
                                          t, spec))
    assert idx < t.highest
    # and the choice still meets demand with headroom
    need = 0.3 * 10.0 * spec.n_units * 1.25
    import math
    n = math.ceil(need / (10.0 * t[idx].perf_scale))
    assert n <= spec.n_units


def test_schedutil_escalates_to_top_when_only_top_feasible():
    spec, t = soc_cluster(), sd865_opp_table()
    # demand ~ full cluster at nominal: nothing slower can meet it
    idx = SchedutilGovernor().select(_ctx(10.0 * spec.n_units * 0.9,
                                          t, spec))
    assert idx == t.highest


def test_thermal_aware_clamps_to_sustainable():
    spec, t = soc_cluster(), sd865_opp_table()
    gov = ThermalAwareGovernor(FixedFreqGovernor())
    assert gov.select(_ctx(5.0, t, spec, max_sustainable=2)) == 2
    # no thermal model -> passthrough
    assert gov.select(_ctx(5.0, t, spec)) == t.highest
    assert isinstance(gov, FreqGovernor)


# ---------------------------------------------------------------------------
# Pool integration: per-unit OPP state + frequency-aware charge.
# ---------------------------------------------------------------------------
def test_pool_charge_single_opp_table_matches_no_dvfs():
    spec = tiny_cluster(8)
    plain = UnitPool(spec)
    dvfs = UnitPool(spec, opp_table=single_opp_table())
    for pool in (plain, dvfs):
        pool.force_active("a", 3)
    t1, p1, n1 = plain.charge(0.0, 1.0, {"a": 0.6})
    t2, p2, n2 = dvfs.charge(0.0, 1.0, {"a": 0.6})
    assert t1 == pytest.approx(t2)
    assert p1["a"] == pytest.approx(p2["a"])
    assert n1 == n2


def test_pool_charge_meters_effective_opp():
    spec = tiny_cluster(8)
    table = sd865_opp_table()
    pool = UnitPool(spec, opp_table=table)
    pool.force_active("a", 2)
    pool.set_opp("a", 1)
    total, per, _ = pool.charge(0.0, 1.0, {"a": 1.0})
    expect = 2 * unit_power(spec.unit, 1.0, table[1])
    assert per["a"] == pytest.approx(expect)
    assert total == pytest.approx(spec.p_shared + expect
                                  + 6 * spec.unit.p_off)
    assert pool.perf_scale("a") == pytest.approx(table[1].perf_scale)


def test_pool_throttle_forces_lowest_opp():
    spec = tiny_cluster(2, group_size=1)
    table = sd865_opp_table()
    pool = UnitPool(spec, opp_table=table,
                    thermal=ThermalParams(t_trip_c=40.0, t_release_c=35.0))
    pool.force_active("a", 1)
    pool.set_opp("a", table.highest)
    u = pool.units_of("a")[0]
    for i in range(300):
        pool.charge(float(i), 1.0, {"a": 1.0})
        if pool.thermal.throttled[u]:
            break
    assert pool.thermal.throttled[u]
    assert pool.effective_opp(u) == table.lowest
    assert pool.perf_scale("a") == pytest.approx(
        table[table.lowest].perf_scale)
    assert pool.max_temp_hist and pool.throttled_hist[-1] == 1


def test_pool_thermal_requires_table():
    with pytest.raises(AssertionError, match="opp_table"):
        UnitPool(tiny_cluster(2), thermal=ThermalParams())


def test_hedged_extra_units_charged_at_tenant_opp():
    spec = tiny_cluster(8)
    table = sd865_opp_table()
    pool = UnitPool(spec, opp_table=table)
    pool.force_active("a", 2)
    pool.set_opp("a", 2)
    _, per, powered = pool.charge(0.0, 1.0, {"a": 1.0}, extra={"a": 1})
    assert powered["a"] == 3
    assert per["a"] == pytest.approx(3 * unit_power(spec.unit, 1.0,
                                                    table[2]))


# ---------------------------------------------------------------------------
# Runtime integration.
# ---------------------------------------------------------------------------
def test_runtime_single_opp_table_matches_no_dvfs_run():
    """The degenerate one-point table must not change a run at all."""
    spec = tiny_cluster(8)
    trace = np.full(40, 4.0)

    def play(**kw):
        rt = ClusterRuntime(spec, QueueWorkload(2.0),
                            policy=ScalePolicy(cooldown_s=5.0), **kw)
        return rt.play_trace(trace, dt_s=1.0)

    a = play()
    b = play(opp_table=single_opp_table())
    assert a.energy_j == pytest.approx(b.energy_j)
    assert a.served == pytest.approx(b.served)
    np.testing.assert_allclose(a.power_w, b.power_w)


def test_runtime_schedutil_saves_energy_at_light_load():
    spec = soc_cluster()
    trace = np.full(150, 10.0 * spec.n_units * 0.25)

    def play(gov, table):
        rt = ClusterRuntime(spec, QueueWorkload(10.0),
                            policy=ScalePolicy(cooldown_s=30.0,
                                               freq_governor=gov),
                            opp_table=table)
        return rt.play_trace(trace, dt_s=1.0)

    base = play(None, None)
    sched = play(SchedutilGovernor(), sd865_opp_table())
    assert sched.energy_j < base.energy_j
    assert sched.served == pytest.approx(base.served, rel=1e-6)
    # wide-and-slow: more units powered on average, each running slower
    assert sched.mean_active > base.mean_active


def test_runtime_perf_scale_gates_throughput():
    """Pinning a slow OPP must slow a backlog drain proportionally."""
    spec = tiny_cluster(4)
    table = sd865_opp_table()

    def drain(gov, table_):
        wl = QueueWorkload(unit_rate=1.0)
        rt = ClusterRuntime(spec, wl,
                            policy=ScalePolicy(min_units=4, cooldown_s=1e9,
                                               freq_governor=gov),
                            opp_table=table_)
        rt.submit(cost=40.0, count=40.0)
        s = rt.tick()
        return s.work_done, s.perf_scale

    w_nom, ps_nom = drain(None, None)
    w_slow, ps_slow = drain(FixedFreqGovernor(1), table)
    assert ps_nom == 1.0
    assert ps_slow == pytest.approx(table[1].perf_scale)
    assert w_slow == pytest.approx(w_nom * table[1].perf_scale)


def test_runtime_throttling_sags_fixed_but_not_aware():
    """Acceptance: sustained peak load trips the fixed-max governor's
    units (throughput sag) but not the thermal-aware governor's."""
    spec = soc_cluster()

    def sustained(gov, ticks=420):
        rt = ClusterRuntime(
            spec, QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(min_units=spec.n_units, cooldown_s=1e9,
                               freq_governor=gov),
            opp_table=sd865_opp_table(), thermal=ThermalParams())
        work = []
        for _ in range(ticks):
            rt.submit(cost=1200.0, count=1200.0)
            work.append(rt.tick().work_done)
        return np.asarray(work), rt

    w_fix, rt_fix = sustained(FixedFreqGovernor())
    w_aware, rt_aware = sustained(ThermalAwareGovernor())
    win = len(w_fix) // 6
    assert w_fix[-win:].mean() < 0.9 * w_fix[:win].mean()
    assert max(rt_fix.pool.throttled_hist) > 0
    assert w_aware[-win:].mean() > 0.95 * w_aware[:win].mean()
    assert max(rt_aware.pool.throttled_hist) == 0


def test_multi_tenant_schedutil_contention_meets_demand():
    """Under contention each tenant's governor must plan with the units
    it can actually obtain, not the whole cluster — otherwise schedutil
    picks a wide-and-slow point arbitration can never grant and
    capacity collapses."""
    from repro.runtime import MultiTenantRuntime, Tenant
    spec = soc_cluster()
    rt = MultiTenantRuntime(spec, [
        Tenant(m, QueueWorkload(10.0, name=m),
               policy=ScalePolicy(cooldown_s=30.0,
                                  freq_governor=SchedutilGovernor()))
        for m in ("a", "b")], dt_s=1.0, opp_table=sd865_opp_table())
    # 290 req/s each: feasible only near the nominal OPP (2x29 units)
    tel = rt.play_traces({"a": np.full(120, 290.0),
                          "b": np.full(120, 290.0)}, dt_s=1.0)
    assert tel.served == pytest.approx(2 * 290.0 * 120, rel=1e-3)
    for m in ("a", "b"):
        assert tel.per_tenant[m].p99_latency_s < 10.0


def test_extra_unit_heat_reaches_thermal_model():
    """Hedged/overflow units are metered for energy AND their heat must
    land on physical silicon, or sustained hedging never throttles."""
    spec = soc_cluster()
    pool = UnitPool(spec, opp_table=sd865_opp_table(),
                    thermal=ThermalParams())
    pool.force_active("a", 2)
    for i in range(50):
        pool.charge(float(i), 60.0, {"a": 1.0}, extra={"a": 10})
    # powered dies sit far above their PCB (P·R_die ≈ 64 K); idle
    # neighbors only ride the board temperature, well below 60 °C
    heated = sum(1 for t in pool.thermal.t_die if t > 60.0)
    assert heated == 12                     # 2 active + 10 borrowed


def test_fluid_latency_floor_respects_perf_scale():
    """A lone request served at a low OPP cannot finish faster than one
    effective (DVFS-scaled) service time."""
    table = sd865_opp_table()
    perf = table[table.lowest].perf_scale
    wl = QueueWorkload(unit_rate=10.0)
    from repro.runtime import Request
    wl.submit(Request(cost=1.0, arrival_s=0.0))
    stats = wl.step(8, dt_s=1.0, t=0.0, perf_scale=perf)
    assert stats.completed == 1
    assert stats.responses[0].finish_s >= 1.0 / (10.0 * perf) - 1e-12


# ---------------------------------------------------------------------------
# Energy-model parity: core.energy vs UnitPool.charge (satellite).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 3, 8])
def test_cluster_power_at_load_matches_pool_charge(k):
    """The closed-form load→power curve and the pool's per-tick charge
    implement the same cluster power formula: for a static single-tenant
    allocation of k fully-utilized units at the default OPP, both give
    p_shared + k·P(1) + rest·P_off."""
    spec = tiny_cluster(8)
    pool = UnitPool(spec, idle_units_off=True)
    pool.force_active("a", k)
    total, _, _ = pool.charge(0.0, 1.0, {"a": 1.0})
    closed_form = cluster_power_at_load(spec, k / spec.n_units,
                                        idle_units_off=True)
    assert total == pytest.approx(closed_form)
    # and the same via the pool's energy integral over one 1 s tick
    assert pool.energy_j == pytest.approx(closed_form)


def test_parity_holds_with_default_opp_table():
    spec = tiny_cluster(8)
    pool = UnitPool(spec, opp_table=sd865_opp_table())
    pool.force_active("a", 4)          # nominal OPP by default
    total, _, _ = pool.charge(0.0, 1.0, {"a": 1.0})
    assert total == pytest.approx(
        cluster_power_at_load(spec, 0.5, idle_units_off=True))


# ---------------------------------------------------------------------------
# Frequency-resolved load→power curve (core.energy).
# ---------------------------------------------------------------------------
def test_dvfs_curve_pointwise_below_binary_same_peak():
    spec, table = soc_cluster(), sd865_opp_table()
    for u in np.linspace(0.0, 1.0, 21):
        p_bin = cluster_power_at_load(spec, float(u))
        p_dvfs = dvfs_power_at_load(spec, table, float(u))
        assert p_dvfs <= p_bin + 1e-9
    assert dvfs_power_at_load(spec, table, 1.0) == pytest.approx(
        cluster_power_at_load(spec, 1.0))


def test_acceptance_dvfs_proportionality_not_worse():
    """Acceptance: the sd865 cluster's proportionality_index does not
    decrease when the frequency-resolved curve replaces the binary one."""
    spec, table = soc_cluster(), sd865_opp_table()
    pi_bin = proportionality_index(spec)
    pi_dvfs = dvfs_proportionality_index(spec, table)
    assert pi_dvfs >= pi_bin - 1e-9
    assert pi_dvfs > 0.9


def test_dvfs_curve_tiny_positive_load_no_crash():
    spec, table = soc_cluster(), sd865_opp_table()
    p = dvfs_power_at_load(spec, table, 1e-15)
    assert p >= spec.p_shared


def test_schedutil_objective_charges_idle_floor_of_gated_units():
    """With idle_units_off=False the gated units' p_idle floor is part
    of the true cluster power; the governor's choice must achieve the
    closed-form minimum of that full objective, not just the active
    term (the two disagree because the active term alone over-penalizes
    wide-and-slow by a floor that is paid either way)."""
    import math
    spec, t = soc_cluster(), sd865_opp_table()
    p_idle = spec.unit.p_idle

    def full_cost(i, rate):
        opp = t[i]
        n = max(1, math.ceil(rate * 1.25 / (10.0 * opp.perf_scale)))
        if n > spec.n_units:
            return float("inf")
        util = min(1.0, rate / (n * 10.0 * opp.perf_scale))
        return n * unit_power(spec.unit, util, opp) \
            + (spec.n_units - n) * p_idle

    for frac in (0.1, 0.3, 0.6):
        rate = frac * 10.0 * spec.n_units
        idx = SchedutilGovernor().select(
            _ctx(rate, t, spec, p_gated_w=p_idle))
        best = min(range(len(t)), key=lambda i: full_cost(i, rate))
        assert full_cost(idx, rate) == pytest.approx(
            full_cost(best, rate))


def test_dvfs_curve_single_point_table_is_binary():
    spec = soc_cluster()
    t = single_opp_table()
    for u in (0.0, 0.2, 0.7, 1.0):
        assert dvfs_power_at_load(spec, t, u) == pytest.approx(
            cluster_power_at_load(spec, u))
