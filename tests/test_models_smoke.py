"""Per-arch smoke tests: reduced config of the same family, one forward /
train step on CPU, asserting output shapes + no NaNs; decode-vs-forward
consistency in fp32."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, smoke_config
from repro.configs import ASSIGNED_ARCHS
from repro.models import model as lm


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jnp.ones((b, s), jnp.int32),
        "labels": jnp.ones((b, s), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.frontend_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (b, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(get_config(arch))
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    logits, _, _ = lm.forward(params, cfg, batch, mode="train")
    s_total = batch["tokens"].shape[1] + cfg.frontend_tokens
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_grad_step_updates_params(arch):
    cfg = smoke_config(get_config(arch))
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    gnorm = np.sqrt(sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                        for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "granite-moe-1b-a400m",
                                  "mamba2-130m", "jamba-1.5-large-398b",
                                  "musicgen-large", "internvl2-1b"])
def test_decode_matches_forward_fp32(arch):
    """prefill(s) + decode(1) must equal the full forward at position s."""
    cfg = smoke_config(get_config(arch)).replace(dtype="float32")
    if cfg.moe is not None:
        # capacity dropping legitimately depends on sequence length; use a
        # drop-free capacity so the equivalence is exact.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    params = lm.init_params(cfg, jax.random.key(1))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(2), (b, s + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    ve = None
    ft = cfg.frontend_tokens
    if ft:
        ve = jnp.asarray(np.random.default_rng(0).standard_normal(
            (b, ft, cfg.frontend_dim or cfg.d_model)), jnp.float32)
        batch["vision_embeds"] = ve
    full, _, _ = lm.forward(params, cfg, batch, mode="train")
    pre_batch = {"tokens": toks[:, :s]}
    if ve is not None:
        pre_batch["vision_embeds"] = ve
    lg_pre, caches = lm.prefill(params, cfg, pre_batch,
                                max_len=s + ft + 8)
    lg_dec, _ = lm.decode_step(params, cfg, toks[:, s:s + 1], caches,
                               pos=s + ft)
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(full[:, s - 1 + ft]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(full[:, s + ft]),
                               rtol=1e-4, atol=1e-4)


def test_scan_equals_unrolled():
    cfg = smoke_config(get_config("jamba-1.5-large-398b")).replace(
        dtype="float32", num_layers=4)
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    l_scan, _, _ = lm.forward(params, cfg, batch, mode="train", scan=True)
    l_unr, _, _ = lm.forward(params, cfg, batch, mode="train", scan=False)
    np.testing.assert_allclose(np.asarray(l_scan), np.asarray(l_unr),
                               rtol=1e-5, atol=1e-5)


def test_remat_preserves_loss():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    l0, _ = lm.loss_fn(params, cfg, batch, remat="none")
    l1, _ = lm.loss_fn(params, cfg, batch, remat="full")
    l2, _ = lm.loss_fn(params, cfg, batch, remat="dots")
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-6)


def test_param_count_matches_headline():
    """Analytic param counts should match the arch ids' headline sizes."""
    expect = {
        "granite-moe-1b-a400m": (1.0e9, 2.0e9),
        "stablelm-12b": (11e9, 13e9),
        "phi3-medium-14b": (13e9, 16e9),
        "qwen2-72b": (70e9, 76e9),
        "internlm2-1.8b": (1.5e9, 2.1e9),
        "mamba2-130m": (0.1e9, 0.16e9),
        "jamba-1.5-large-398b": (380e9, 410e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).num_params
        assert lo <= n <= hi, (arch, n)


def test_active_params_moe():
    g = get_config("granite-moe-1b-a400m")
    assert g.num_active_params < 0.6e9  # "a400m" + embeddings
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.num_active_params < 0.05 * l4.num_params
