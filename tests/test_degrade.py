"""Graceful-degradation control plane (``repro.fleet.degrade``).

Covers the parity contract for the degradation layer — SLO-tiered
admission, deadline load shedding, per-rack circuit breakers, and
deterministic seeded retry — across all three fleet engines:
scalar/vector bitwise (including shed/retry/breaker counters), jax
within documented tolerances. The randomized lockstep test is a
hypothesis property test when hypothesis is installed and a seeded
fan of examples otherwise; either way the configs and chaos schedules
derive from ``chaos_seed()`` so CI failures reproduce locally with
``REPRO_CHAOS_SEED=<n> pytest tests/test_degrade.py``.

Also here: the extended conservation identity
(injected = served + chaos-dropped + deadline-expired + retry-dropped),
a deliberate-corruption test proving the sanitizer catches a leaked
shed count, the breaker state machine end to end, trace instants for
breaker transitions, and the ``shed_storm`` SLO rule.
"""
import numpy as np
import pytest

from repro.core.cluster import soc_cluster
from repro.distributed.fault import RetryPolicy
from repro.fleet import (BreakerConfig, ChaosMonitor, ChaosSchedule,
                         DegradePolicy, Fleet, TierSpec, chaos_seed,
                         diurnal_trace, homogeneous_fleet,
                         tier_latency_percentiles)
from repro.fleet.degrade import BRK_CLOSED, BRK_HALF, BRK_OPEN
from repro.obs import FleetObs, ShedStormRule, SloPolicy
from repro.obs.trace import build_chrome_trace, validate_chrome_trace
from repro.runtime import ScalePolicy
from repro.runtime.result import Request
from repro.runtime.sanitize import InvariantViolation
from repro.runtime.workload import QueueWorkload

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: seeded fan below
    HAVE_HYPOTHESIS = False

UNIT_RATE = 30.0
DT_S = 60.0
HOUR = 3600.0
N_RACKS = 4
FLEET_CAP = N_RACKS * 60 * UNIT_RATE  # rps at full activation

#: jax aggregate tolerance for degrade counters (same contract as fig16)
JAX_RTOL = 1e-9


def _racks(n=N_RACKS):
    return homogeneous_fleet(
        soc_cluster(), n, UNIT_RATE,
        policy=ScalePolicy(cooldown_s=300.0, min_units=1))


def _saturating_trace(ticks=120, seed=7):
    """Base load ~30% of capacity with a 30-tick flash crowd at ~1.8x
    capacity — deep enough to exercise shed, expiry, retry drops, and
    breaker trips (the non-vacuous fixture the smoke tests use)."""
    rng = np.random.default_rng(seed)
    t = np.arange(ticks)
    rps = 2200.0 * (1.0 + 0.2 * np.sin(t / 8.0)) \
        + rng.normal(0, 40.0, ticks)
    rps = np.clip(rps, 0.0, None)
    rps[40:70] *= 6.0
    return rps


def _full_policy():
    return DegradePolicy(
        tiers=(TierSpec("gold", 0.2, 900.0),
               TierSpec("silver", 0.3, 420.0),
               TierSpec("bulk", 0.5, 180.0)),
        queue_deadline_s=900.0,
        breaker=BreakerConfig(open_after_s=300.0, close_below_s=120.0,
                              cooldown_s=600.0, probe_fraction=0.25,
                              fail_timeout_s=120.0),
        retry=RetryPolicy(max_attempts=3, backoff_s=120.0, jitter=0.5),
        seed=11)


def _kill_schedule():
    return ChaosSchedule().kill_rack(1, 10 * DT_S, 25 * DT_S)


def _fleet(backend, *, degrade, chaos=None, obs=None):
    return Fleet(_racks(), dt_s=DT_S, backend=backend, chaos=chaos,
                 degrade=degrade, sanitize=True, obs=obs)


def _random_policy(rng):
    """One random-but-valid degradation plan (any mechanism may be off,
    mirroring the declarative knobs users actually get)."""
    n_tiers = int(rng.integers(1, 4))
    shares = rng.dirichlet(np.ones(n_tiers) * 2.0)
    shares = np.round(shares, 6)
    shares[-1] = 1.0 - float(shares[:-1].sum())
    budgets = np.sort(rng.uniform(120.0, 1200.0, n_tiers))[::-1]
    tiers = tuple(
        TierSpec(f"t{k}", float(shares[k]), float(budgets[k]))
        for k in range(n_tiers)) if rng.random() < 0.85 else ()
    breaker = None
    if rng.random() < 0.7:
        open_after = float(rng.uniform(240.0, 900.0))
        breaker = BreakerConfig(
            open_after_s=open_after,
            close_below_s=float(rng.uniform(30.0, open_after - 60.0)),
            cooldown_s=float(rng.uniform(300.0, 1200.0)),
            probe_fraction=float(rng.uniform(0.05, 0.5)),
            use_chaos_signal=bool(rng.random() < 0.5),
            fail_timeout_s=float(rng.uniform(60.0, 300.0)))
    return DegradePolicy(
        tiers=tiers,
        queue_deadline_s=(float(rng.uniform(300.0, 1200.0))
                          if rng.random() < 0.7 else None),
        breaker=breaker,
        retry=RetryPolicy(max_attempts=int(rng.integers(1, 5)),
                          backoff_s=float(rng.uniform(60.0, 240.0)),
                          jitter=float(rng.uniform(0.0, 1.0))),
        seed=int(rng.integers(1, 2**31)))


def _assert_lockstep(seed):
    """The property under test: a random plan + random chaos schedule,
    replayed through scalar and vector under the sanitizer, stays
    bitwise-identical — degrade counters included — and conserves
    injected mass once drained."""
    rng = np.random.default_rng(seed)
    policy = _random_policy(rng)
    horizon = 100 * DT_S
    sched = ChaosSchedule.random(N_RACKS, horizon,
                                 seed=int(rng.integers(2**31)), n_events=3)
    peak = float(rng.uniform(0.5, 1.4)) * FLEET_CAP
    trace = diurnal_trace(peak_rps=peak, hours=horizon / HOUR, dt_s=DT_S)

    ts = _fleet("scalar", degrade=policy, chaos=sched).play_trace(trace)
    tv = _fleet("vector", degrade=policy, chaos=sched).play_trace(trace)
    ctx = f"seed={seed}"
    assert ts.served == tv.served, ctx
    assert ts.energy_j == tv.energy_j, ctx
    assert np.array_equal(ts.power_w, tv.power_w), ctx
    assert np.array_equal(ts.queued, tv.queued), ctx
    assert ts.p99_latency_s == tv.p99_latency_s, ctx
    # degrade counters are part of the bitwise contract
    assert ts.shed_cost == tv.shed_cost, ctx
    assert ts.shed_by_tier == tv.shed_by_tier, ctx
    assert np.array_equal(ts.shed_cost_t, tv.shed_cost_t), ctx
    assert ts.expired_requests == tv.expired_requests, ctx
    assert ts.expired_cost == tv.expired_cost, ctx
    assert ts.retried_cost == tv.retried_cost, ctx
    assert ts.retry_dropped_cost == tv.retry_dropped_cost, ctx
    assert ts.breaker_opens == tv.breaker_opens, ctx
    assert np.array_equal(ts.breaker_state_t, tv.breaker_state_t), ctx
    assert ts.breaker_events == tv.breaker_events, ctx
    # extended conservation: everything injected is served or lands in
    # exactly one terminal sink (chaos drop, deadline expiry, retry
    # budget exhaustion) — shed mass is a flow, not a sink
    if tv.drained:
        injected = float(np.sum(trace)) * DT_S
        balance = tv.served + tv.dropped_cost + tv.expired_cost + \
            tv.retry_dropped_cost
        assert balance == pytest.approx(injected, rel=1e-6), ctx


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_lockstep_random_policies(seed):
        _assert_lockstep(seed)
else:
    @pytest.mark.parametrize("case", range(6))
    def test_lockstep_random_policies(case):
        _assert_lockstep(chaos_seed(default=20260808) * 100 + case)


# ---------------------------------------------------------------------------
# Non-vacuous bitwise parity: every mechanism actually fires.
# ---------------------------------------------------------------------------
def test_scalar_vector_bitwise_all_mechanisms_active():
    trace = _saturating_trace()
    ts = _fleet("scalar", degrade=_full_policy(),
                chaos=_kill_schedule()).play_trace(trace)
    tv = _fleet("vector", degrade=_full_policy(),
                chaos=_kill_schedule()).play_trace(trace)
    # all four mechanisms fired (vacuity guard)
    assert tv.shed_cost > 0.0
    assert tv.expired_cost > 0.0
    assert tv.retried_cost > 0.0
    assert tv.retry_dropped_cost > 0.0
    assert tv.breaker_opens > 0
    assert ts.served == tv.served
    assert ts.energy_j == tv.energy_j
    assert ts.shed_cost == tv.shed_cost
    assert ts.shed_by_tier == tv.shed_by_tier
    assert ts.expired_requests == tv.expired_requests
    assert ts.expired_cost == tv.expired_cost
    assert ts.retried_cost == tv.retried_cost
    assert ts.retry_dropped_cost == tv.retry_dropped_cost
    assert ts.breaker_opens == tv.breaker_opens
    assert np.array_equal(ts.breaker_state_t, tv.breaker_state_t)
    # bulk (loosest budget) sheds most; gold (tightest) least
    assert tv.shed_by_tier["bulk"] >= tv.shed_by_tier["gold"]


# ---------------------------------------------------------------------------
# Jax tolerance parity on the degrade aggregates.
# ---------------------------------------------------------------------------
def test_jax_degrade_parity():
    pytest.importorskip("jax")
    trace = _saturating_trace()

    def run(backend):
        return _fleet(backend, degrade=_full_policy(),
                      chaos=_kill_schedule()).play_trace(trace)

    tv, tj = run("vector"), run("jax")
    assert tv.shed_cost > 0.0 and tv.breaker_opens > 0  # non-vacuous
    assert np.isclose(tv.served, tj.served, rtol=JAX_RTOL)
    assert np.isclose(tv.energy_j, tj.energy_j, rtol=JAX_RTOL)
    assert np.isclose(tv.shed_cost, tj.shed_cost, rtol=JAX_RTOL)
    assert np.isclose(tv.expired_cost, tj.expired_cost, rtol=JAX_RTOL)
    assert np.isclose(tv.retried_cost, tj.retried_cost, rtol=JAX_RTOL)
    assert np.isclose(tv.retry_dropped_cost, tj.retry_dropped_cost,
                      rtol=JAX_RTOL)
    assert np.isclose(tv.p99_latency_s, tj.p99_latency_s, rtol=JAX_RTOL)
    # breakers run on integer tick state: exactly equal, whole series
    assert tv.breaker_opens == tj.breaker_opens
    assert np.array_equal(tv.breaker_state_t, tj.breaker_state_t)
    assert np.allclose(tv.shed_cost_t, tj.shed_cost_t, rtol=JAX_RTOL,
                       atol=1e-9)
    # retried mass re-enters the offered series identically
    assert len(tv.offered_rps) == len(tj.offered_rps)
    assert np.allclose(tv.offered_rps, tj.offered_rps, rtol=JAX_RTOL,
                       atol=1e-9)
    assert tv.ticks == tj.ticks and tv.drained == tj.drained
    # the jax host-side reconstruction expands each tick into the same
    # per-tier sub-requests the hosts submit: response *counts* match
    # exactly per rack, and tier-tagged percentiles within tolerance
    for rv, rj in zip(tv.per_rack, tj.per_rack):
        assert len(rv.responses) == len(rj.responses)
    for tier in ("gold", "silver", "bulk"):
        pv = tier_latency_percentiles(tv, tier)
        pj = tier_latency_percentiles(tj, tier)
        assert pv[99.0] > 0.0  # non-vacuous: every tier completed work
        for q in pv:
            assert np.isclose(pv[q], pj[q], rtol=JAX_RTOL), (tier, q)
    # conservation closes for both engines
    injected = float(np.sum(trace)) * DT_S
    for tel in (tv, tj):
        balance = tel.served + tel.dropped_cost + tel.expired_cost + \
            tel.retry_dropped_cost
        assert balance == pytest.approx(injected, rel=1e-6)


# ---------------------------------------------------------------------------
# Sanitizer: a leaked shed count is trapped.
# ---------------------------------------------------------------------------
def test_sanitizer_traps_leaked_shed_count():
    """Deadline-expired mass is a conservation credit; inflating it
    without removing the matching queued work must trip the extended
    conservation check (a real leak — e.g. expiry double-counting —
    would corrupt the ledger exactly this way)."""
    fleet = _fleet("vector", degrade=_full_policy(), chaos=_kill_schedule())
    fleet.play_trace(_saturating_trace())
    san = fleet._sanitizer
    san.check()  # clean run passes
    fleet.engine.degrade_expired_by_rack[1] += 1e6
    with pytest.raises(InvariantViolation, match="conservation"):
        san.check()


# ---------------------------------------------------------------------------
# Mechanism-level units.
# ---------------------------------------------------------------------------
def test_retry_policy_jitter_is_seeded_and_clock_free():
    p = RetryPolicy(max_attempts=4, backoff_s=100.0, jitter=0.5, seed=9)
    q = RetryPolicy(max_attempts=4, backoff_s=100.0, jitter=0.5, seed=9)
    # pure function of (seed, key): identical across instances/replays
    us = [p.jitter_u(k) for k in range(32)]
    assert us == [q.jitter_u(k) for k in range(32)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert len(set(us)) > 1  # actually varies by key
    other = RetryPolicy(max_attempts=4, backoff_s=100.0, jitter=0.5, seed=10)
    assert us != [other.jitter_u(k) for k in range(32)]
    # exponential base, jitter widens, bound holds
    assert p.delay_s(1) == 200.0
    assert p.delay_s(1, 1.0) == 300.0
    assert p.max_delay_s == p.delay_s(3, 1.0)


def test_queue_expire_pops_stale_head_only():
    wl = QueueWorkload(unit_rate=1.0)
    for arrival in (0.0, 10.0, 100.0):
        wl.submit(Request(cost=5.0, arrival_s=arrival))
    n, cost = wl.expire(now=70.0, deadline_s=60.0)  # cutoff ~10.0
    assert (n, cost) == (2, 10.0)
    assert len(wl._queue) == 1  # the fresh request survives
    assert wl.expire(now=70.0, deadline_s=60.0) == (0, 0.0)  # idempotent


def test_policy_validation():
    with pytest.raises(ValueError, match="sum to 1"):
        DegradePolicy(tiers=(TierSpec("a", 0.5, 100.0),
                             TierSpec("b", 0.2, 50.0)))
    with pytest.raises(ValueError, match="open above"):
        BreakerConfig(open_after_s=100.0, close_below_s=200.0)
    with pytest.raises(ValueError, match="probe_fraction"):
        BreakerConfig(probe_fraction=0.0)
    with pytest.raises(ValueError):
        DegradePolicy(tiers=(), queue_deadline_s=-1.0)


def test_breaker_state_machine_full_cycle():
    """A chaos kill trips rack 1's breaker via the failure signal; after
    restoration + cooldown it half-opens with probe traffic, then closes
    — the full CLOSED→OPEN→HALF→CLOSED cycle on the sim clock."""
    # queue-delay tripping effectively disabled (open_after_s huge) so
    # the only trip signal is the chaos failure detector — the cycle is
    # then deterministic and confined to the killed rack
    policy = DegradePolicy(
        tiers=(), queue_deadline_s=None,
        breaker=BreakerConfig(open_after_s=1e5, close_below_s=120.0,
                              cooldown_s=300.0, probe_fraction=0.25,
                              use_chaos_signal=True, fail_timeout_s=120.0),
        retry=RetryPolicy(max_attempts=1, backoff_s=60.0))
    trace = np.full(80, 0.4 * FLEET_CAP)
    tel = _fleet("vector", degrade=policy,
                 chaos=_kill_schedule()).play_trace(trace)
    states = tel.breaker_state_t[1]
    assert BRK_OPEN in states and BRK_HALF in states
    assert states[-1] == BRK_CLOSED  # recovered by end of run
    # ordered transitions: open before half-open before the final close
    first_open = int(np.argmax(states == BRK_OPEN))
    first_half = int(np.argmax(states == BRK_HALF))
    assert first_open < first_half
    assert tel.breaker_opens >= 1
    ev = tel.breaker_events[0]
    assert ev["state"] == BRK_OPEN and ev["prev"] == BRK_CLOSED
    assert ev["rack"] == tel.rack_names[1]
    # healthy racks never trip
    assert np.all(tel.breaker_state_t[0] == BRK_CLOSED)


def test_chaos_monitor_failed_mask():
    mon = ChaosMonitor(3, timeout_s=120.0)
    n_units = np.full(3, 64, np.int64)
    dead = np.zeros(3, np.int64)
    dead[1] = 64
    for t in (0.0, 60.0, 120.0, 180.0):
        mon.observe(t, dead, n_units)
    mask = mon.failed_mask(3)
    assert mask.dtype == bool and mask.tolist() == [False, True, False]
    assert mon.failed_mask(1).tolist() == [False]  # out-of-range rack ok


# ---------------------------------------------------------------------------
# Observability: breaker trace instants + shed_storm SLO rule.
# ---------------------------------------------------------------------------
def test_breaker_transitions_appear_as_trace_instants():
    tel = _fleet("vector", degrade=_full_policy(),
                 chaos=_kill_schedule()).play_trace(_saturating_trace())
    assert tel.breaker_opens > 0  # non-vacuous
    trace = build_chrome_trace(tel)
    assert validate_chrome_trace(trace) == []
    instants = [ev for ev in trace["traceEvents"]
                if ev.get("cat") == "degrade"]
    assert instants, "breaker transitions missing from the chrome trace"
    names = {ev["name"] for ev in instants}
    assert "breaker_open" in names
    # each instant rides the afflicted rack's own track
    by_name = {n: i + 1 for i, n in enumerate(tel.rack_names)}
    for ev in instants:
        assert ev["tid"] == by_name[ev["args"]["rack"]]
        assert ev["args"]["state"] in ("open", "half_open", "closed")


def test_shed_storm_rule_fires_on_sustained_shedding():
    slo = SloPolicy([ShedStormRule(max_shed_rps=50.0, window_s=1800.0)])
    tel = _fleet("vector", degrade=_full_policy(), chaos=_kill_schedule(),
                 obs=FleetObs(slo=slo)).play_trace(_saturating_trace())
    assert tel.shed_cost > 0.0
    storms = [a for a in tel.alerts if a.rule == "shed_storm"]
    assert storms, "flash-crowd shedding should trip the shed_storm rule"
    assert all(a.severity == "critical" for a in storms)
    assert storms[0].worst_value > 50.0


def test_shed_storm_rule_inert_without_degrade():
    slo = SloPolicy([ShedStormRule(max_shed_rps=0.0)])
    tel = _fleet("vector", degrade=None,
                 obs=FleetObs(slo=slo)).play_trace(_saturating_trace(60))
    assert not [a for a in tel.alerts if a.rule == "shed_storm"]


def test_tier_latency_percentiles_split_by_tier():
    tel = _fleet("vector", degrade=_full_policy()).play_trace(
        _saturating_trace())
    gold = tier_latency_percentiles(tel, "gold")
    bulk = tier_latency_percentiles(tel, "bulk")
    assert set(gold) == {50.0, 99.0}
    assert gold[99.0] > 0.0 and bulk[99.0] > 0.0
    assert tier_latency_percentiles(tel, "no-such-tier") == \
        {50.0: 0.0, 99.0: 0.0}
