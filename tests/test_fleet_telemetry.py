"""FleetTelemetry roll-ups: duration under non-uniform tick spacing,
the energy/TCO bridges, proportionality edge cases, and the
``drained=False`` sustained-overload path."""
import numpy as np
import pytest

from repro.core.cluster import soc_cluster
from repro.core.tco import ELECTRICITY_USD_PER_KWH, PUE_EDGE
from repro.fleet import Fleet, JoinShortestQueueRouter, homogeneous_fleet
from repro.fleet.telemetry import FleetTelemetry, empirical_proportionality
from repro.runtime import ScalePolicy
from repro.runtime.result import Telemetry


def _mk(time_s, power_rows, **kw):
    power_rows = np.asarray(power_rows, float)
    racks, ticks = power_rows.shape
    defaults = dict(
        time_s=np.asarray(time_s, float),
        offered_rps=np.zeros(ticks),
        assigned_rps=np.zeros((racks, ticks)),
        active_units=np.ones((racks, ticks)),
        power_w=power_rows,
        queued=np.zeros((racks, ticks), np.int64),
        served=float(ticks),
        energy_j=float(power_rows.sum() * 60.0),
        p50_latency_s=0.1,
        p95_latency_s=0.2,
        p99_latency_s=0.3,
    )
    defaults.update(kw)
    return FleetTelemetry(**defaults)


# ---------------------------------------------------------------------------
# duration_s: actual tick deltas, not an assumed uniform grid.
# ---------------------------------------------------------------------------
def test_duration_uniform_spacing():
    tel = _mk([0.0, 60.0, 120.0], np.ones((2, 3)))
    assert tel.duration_s == 180.0


def test_duration_nonuniform_spacing_uses_actual_deltas():
    # stitched trace: deltas 1, 2, 4 — covered time is span + last width
    # = (7 - 0) + (7 - 3) = 11, NOT ticks * first_delta = 4
    tel = _mk([0.0, 1.0, 3.0, 7.0], np.ones((1, 4)))
    assert tel.duration_s == 11.0
    per_rack = Telemetry(time_s=np.array([0.0, 1.0, 3.0, 7.0]))
    assert per_rack.duration_s == 11.0


def test_duration_degenerate_lengths():
    assert _mk(np.zeros(0), np.ones((1, 0)), served=0.0).duration_s == 0.0
    assert _mk([5.0], np.ones((1, 1))).duration_s == 1.0
    assert Telemetry(time_s=np.zeros(0)).duration_s == 0.0
    assert Telemetry(time_s=np.array([3.0])).duration_s == 1.0


def test_throughput_uses_covered_duration():
    tel = _mk([0.0, 1.0, 3.0, 7.0], np.ones((1, 4)), served=22.0)
    assert tel.throughput == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# empirical_proportionality edges.
# ---------------------------------------------------------------------------
def test_proportionality_empty_series_is_zero():
    assert empirical_proportionality(np.zeros(0), np.zeros(0)) == 0.0


def test_proportionality_zero_max_is_zero():
    assert empirical_proportionality(np.array([1.0, 2.0]),
                                     np.zeros(2)) == 0.0
    assert empirical_proportionality(np.zeros(2),
                                     np.array([1.0, 2.0])) == 0.0


def test_proportionality_perfect_tracking_is_one():
    load = np.array([10.0, 20.0, 40.0])
    assert empirical_proportionality(load, 7.5 * load) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Energy/TCO bridges.
# ---------------------------------------------------------------------------
def test_energy_report_bridge_fields():
    power = np.array([[100.0, 200.0, 300.0], [50.0, 50.0, 50.0]])
    tel = _mk([0.0, 60.0, 120.0], power, served=90.0)
    rep = tel.energy_report()
    assert rep.joules == tel.energy_j
    assert rep.avg_power_w == tel.mean_power_w == pytest.approx(250.0)
    assert rep.peak_power_w == tel.peak_power_w == 350.0
    assert rep.items == 90.0
    assert rep.tpe == tel.tpe
    assert rep.proportionality == tel.proportionality()


def test_monthly_electricity_formula():
    tel = _mk([0.0, 60.0], np.full((1, 2), 1000.0))
    # 1 kW mean -> 720 kWh/month, priced at the EIA rate x PUE
    expect = 720.0 * ELECTRICITY_USD_PER_KWH * PUE_EDGE
    assert tel.monthly_electricity_usd() == pytest.approx(expect)
    assert tel.monthly_electricity_usd(pue=1.0) == pytest.approx(
        720.0 * ELECTRICITY_USD_PER_KWH)


def test_summary_zero_tick_edge():
    tel = _mk(np.zeros(0), np.ones((2, 0)), served=0.0, energy_j=0.0)
    s = tel.summary()
    assert s["mean_power_w"] == 0.0
    assert s["peak_power_w"] == 0.0
    assert s["mean_active_units"] == 0.0
    assert s["proportionality"] == 0.0
    assert s["monthly_electricity_usd"] == 0.0


# ---------------------------------------------------------------------------
# Sustained overload: drained=False surfaces in the roll-up.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["scalar", "vector"])
def test_overload_sets_drained_false(backend):
    racks = homogeneous_fleet(soc_cluster(), 2, unit_rate=30.0,
                              policy=ScalePolicy(cooldown_s=300.0))
    fleet = Fleet(racks, router=JoinShortestQueueRouter(), dt_s=60.0,
                  backend=backend)
    # 40x capacity for 3 ticks: the 10x-trace drain cap cannot clear it
    tel = fleet.play_trace([40.0 * fleet.capacity_rps] * 3)
    assert tel.drained is False
    assert tel.queued[:, -1].sum() > 0
    assert tel.summary()["drained"] == 0.0


def test_normal_run_sets_drained_true():
    racks = homogeneous_fleet(soc_cluster(), 2, unit_rate=30.0,
                              policy=ScalePolicy(cooldown_s=300.0))
    fleet = Fleet(racks, router=JoinShortestQueueRouter(), dt_s=60.0,
                  backend="vector")
    tel = fleet.play_trace([0.3 * fleet.capacity_rps] * 5)
    assert tel.drained is True
    assert tel.summary()["drained"] == 1.0
    assert tel.summary()["alerts"] == 0.0
