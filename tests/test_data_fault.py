"""Data pipeline determinism + straggler hedging; fault-tolerance logic."""

import numpy as np
import pytest

from repro.distributed.fault import (HealthTracker, elastic_step_scale,
                                     shrink_mesh_shape, with_retries)
from repro.training.data import DataConfig, PrefetchingLoader, _gen_batch


def test_batches_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b1 = _gen_batch(cfg, 7)
    b2 = _gen_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = _gen_batch(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = _gen_batch(cfg, 0)
    assert b["tokens"].shape == (2, 16)
    assert b["labels"].shape == (2, 16)
    # label[t] is the next token in the underlying sequence; the first 15
    # labels equal tokens shifted by one
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_straggler_hedge_is_bit_identical():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=1)
    slow = PrefetchingLoader(
        cfg, fetch_deadline_s=0.05,
        delay_injector=lambda step: 0.5 if step == 2 else 0.0)
    fast = PrefetchingLoader(cfg)
    for step in range(4):
        b_slow = slow.get(step)
        b_fast = fast.get(step)
        np.testing.assert_array_equal(b_slow["tokens"], b_fast["tokens"])
    assert slow.hedge_count >= 1
    assert fast.hedge_count == 0


def test_health_tracker_detects_failures_and_stragglers():
    t = [0.0]
    clock = lambda: t[0]
    h = HealthTracker(range(4), timeout_s=20.0, straggler_factor=2.0,
                      clock=clock)
    for _step in range(8):
        t[0] += 1.0
        for u in range(3):
            h.heartbeat(u, step_time=1.0 if u != 2 else 5.0)
        # unit 3 never heartbeats
    t[0] += 15.0
    assert 3 in h.failed_units()
    assert h.healthy_units() == [0, 1, 2]
    assert h.stragglers() == [2]


def test_shrink_mesh_shape():
    # losing 3 units on a (16, 16) mesh drops one data slice
    assert shrink_mesh_shape((16, 16), ("data", "model"), 3) == (15, 16)
    assert shrink_mesh_shape((16, 16), ("data", "model"), 17) == (14, 16)
    assert shrink_mesh_shape((2, 16, 16), ("pod", "data", "model"), 1,
                             shrink_axis="data") == (2, 15, 16)


def test_elastic_step_scale_keeps_global_batch():
    micro, lr = elastic_step_scale(256, old_data=16, new_data=8)
    assert micro * 8 * (256 // 16) >= 256
    assert lr == 1.0


def test_with_retries():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert with_retries(flaky, max_attempts=5, backoff_s=0.0)() == "ok"
    assert len(calls) == 3

    def hopeless():
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        with_retries(hopeless, max_attempts=2, backoff_s=0.0)()
