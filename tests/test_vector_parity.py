"""Scalar <-> vector backend parity: the vectorized pool must reproduce
the scalar reference **bitwise** — energy integrals (fig7/fig14),
latency percentiles, and temperature/throttle/fan histograms (fig15) —
across every simulation path: plain gating, multi-tenant arbitration,
straggler hedging, DVFS governors, and thermal throttling."""
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec, UnitSpec, soc_cluster
from repro.core.scheduler import diurnal_trace
from repro.power import (FixedFreqGovernor, SchedutilGovernor, ThermalParams,
                         sd865_opp_table)
from repro.runtime import (ClusterRuntime, MultiTenantRuntime, QueueWorkload,
                           Request, ScalePolicy, Tenant, UnitPool,
                           VectorUnitPool, make_unit_pool)

BACKENDS = ("scalar", "vector")


def tiny_spec(n=6, group=3):
    return ClusterSpec(
        name="tiny", n_units=n, p_shared=10.0, group_size=group,
        unit=UnitSpec("u", p_off=0.0, p_idle=0.5, p_peak=4.0, gamma=1.0))


def assert_telemetry_equal(a, b, thermal=False):
    """Bitwise comparison of every fig7/fig14/fig15-relevant field."""
    assert np.array_equal(a.time_s, b.time_s)
    assert np.array_equal(a.power_w, b.power_w)
    assert np.array_equal(a.active_units, b.active_units)
    assert np.array_equal(a.utilization, b.utilization)
    assert np.array_equal(a.offered_load, b.offered_load)
    assert a.energy_j == b.energy_j                    # energy integral
    assert a.unit_energy_j == b.unit_energy_j
    assert a.served == b.served
    assert a.hedged == b.hedged
    assert a.scale_events == b.scale_events
    assert a.p50_latency_s == b.p50_latency_s
    assert a.p99_latency_s == b.p99_latency_s
    la = sorted(r.latency_s for r in a.responses)
    lb = sorted(r.latency_s for r in b.responses)
    assert la == lb


def assert_pool_hists_equal(pa, pb):
    assert pa.power_hist == [float(x) for x in pb.power_hist]
    assert pa.max_temp_hist == [float(x) for x in pb.max_temp_hist]
    assert pa.throttled_hist == [int(x) for x in pb.throttled_hist]
    assert pa.fan_power_hist == [float(x) for x in pb.fan_power_hist]


# ---------------------------------------------------------------------------
# fig7-style: single tenant, binary gating, diurnal energy integral.
# ---------------------------------------------------------------------------
def test_single_tenant_diurnal_bitwise():
    def run(backend):
        rt = ClusterRuntime(
            soc_cluster(), QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(cooldown_s=120.0), dt_s=60.0,
            backend=backend)
        trace = diurnal_trace(peak_rps=550.0, hours=4, dt_s=60.0, seed=0)
        return rt.play_trace(trace, dt_s=60.0)

    assert_telemetry_equal(run("scalar"), run("vector"))


# ---------------------------------------------------------------------------
# fig14-style: three tenants, anti-phase diurnal, hedging enabled.
# ---------------------------------------------------------------------------
def _mixed_run(backend):
    spec = soc_cluster()
    wls = {m: QueueWorkload(unit_rate=r, name=m)
           for m, r in (("transcode", 16.0), ("dl", 30.0), ("lm", 8.0))}
    rt = MultiTenantRuntime(
        spec,
        [Tenant(m, wl, policy=ScalePolicy(cooldown_s=120.0, min_units=2,
                                          hedge_after_s=240.0))
         for m, wl in wls.items()],
        dt_s=60.0, backend=backend)
    n = int(4 * 3600 / 60)
    traces = {}
    for i, (m, wl) in enumerate(wls.items()):
        tr = diurnal_trace(peak_rps=wl.unit_rate * spec.n_units * 0.45,
                           hours=4, dt_s=60.0, seed=i)
        traces[m] = np.roll(tr, i * n // 3)
    return rt.play_traces(traces, dt_s=60.0)


def test_multi_tenant_bitwise():
    ts, tv = _mixed_run("scalar"), _mixed_run("vector")
    assert_telemetry_equal(ts, tv)
    for m in ts.per_tenant:
        assert_telemetry_equal(ts.per_tenant[m], tv.per_tenant[m])


def _hedging_run(backend):
    """A burst that outruns the governor window so backlog ages past the
    hedge deadline while free units exist: hedging must actually fire."""
    spec = tiny_spec(n=6, group=1)
    rt = ClusterRuntime(
        spec, QueueWorkload(unit_rate=2.0),
        policy=ScalePolicy(headroom=1.0, cooldown_s=1e9,
                           hedge_after_s=1.5),
        dt_s=1.0, window_s=30.0, backend=backend)
    for _ in range(5):
        rt.submit(cost=6.0, count=6.0)
        rt.tick()
    for _ in range(40):
        if rt.tick().queued == 0:
            break
    return rt.telemetry()


def test_hedging_parity_and_fires():
    ts, tv = _hedging_run("scalar"), _hedging_run("vector")
    assert ts.hedged == tv.hedged
    assert ts.hedged > 0, "scenario must exercise the hedging path"
    assert_telemetry_equal(ts, tv)


# ---------------------------------------------------------------------------
# fig15-style: DVFS governors + thermal throttling histograms.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("governor", [None, FixedFreqGovernor(),
                                      SchedutilGovernor()])
def test_dvfs_thermal_bitwise(governor):
    def run(backend):
        spec = soc_cluster()
        rt = ClusterRuntime(
            spec, QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(min_units=spec.n_units, cooldown_s=1e9,
                               freq_governor=governor),
            opp_table=sd865_opp_table(),
            # low trip point: the latch must engage within the short run
            thermal=ThermalParams(t_trip_c=70.0, t_release_c=60.0),
            dt_s=1.0, backend=backend)
        offered = 2.0 * 10.0 * spec.n_units       # sustained overload
        for _ in range(240):
            rt.submit(cost=offered, count=offered)
            rt.tick()
        return rt

    rs, rv = run("scalar"), run("vector")
    assert_pool_hists_equal(rs.pool, rv.pool)
    assert rs.pool.energy_j == rv.pool.energy_j
    if isinstance(governor, FixedFreqGovernor):
        assert max(rs.pool.throttled_hist) > 0, \
            "fixed-max under sustained overload must trip the latch"


def test_schedutil_low_load_energy_bitwise():
    def run(backend):
        rt = ClusterRuntime(
            soc_cluster(), QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(freq_governor=SchedutilGovernor()),
            opp_table=sd865_opp_table(), dt_s=1.0, backend=backend)
        trace = np.full(120, 0.3 * 10.0 * 60)
        return rt.play_trace(trace, dt_s=1.0)

    assert_telemetry_equal(run("scalar"), run("vector"))


# ---------------------------------------------------------------------------
# Randomized pool transition sequences (placement, release order, OPPs).
# ---------------------------------------------------------------------------
def _snapshot(pool):
    return (list(pool.state), list(pool.owner),
            [pool.active(m) for m in ("a", "b", "c")],
            [pool.waking(m) for m in ("a", "b", "c")],
            pool.n_allocated(), pool.energy_j, pool.tenant_energy_j)


def test_random_op_sequences_identical():
    rng = np.random.default_rng(42)
    spec = tiny_spec(n=10, group=5)
    ps = make_unit_pool(spec, backend="scalar",
                        opp_table=sd865_opp_table(), thermal=ThermalParams())
    pv = make_unit_pool(spec, backend="vector",
                        opp_table=sd865_opp_table(), thermal=ThermalParams())
    assert isinstance(ps, UnitPool) and isinstance(pv, VectorUnitPool)
    tenants = ("a", "b", "c")
    t = 0.0
    for step in range(300):
        op = rng.integers(0, 6)
        m = tenants[rng.integers(0, 3)]
        k = int(rng.integers(0, 5))
        if op == 0:
            assert ps.wake(m, k, t + 1.0) == pv.wake(m, k, t + 1.0)
        elif op == 1:
            assert ps.release(m, k) == pv.release(m, k)
        elif op == 2:
            assert ps.advance(t, 1.0) == pv.advance(t, 1.0)
        elif op == 3:
            ps.force_active(m, k)
            pv.force_active(m, k)
        elif op == 4:
            idx = int(rng.integers(0, 5))
            ps.set_opp(m, idx)
            pv.set_opp(m, idx)
        else:
            utils = {m2: float(rng.random()) for m2 in tenants}
            extra = {m: k % 3}
            rs = ps.charge(t, 1.0, utils, extra)
            rv = pv.charge(t, 1.0, utils, extra)
            assert rs[0] == rv[0] and rs[1] == rv[1] and rs[2] == rv[2]
        assert _snapshot(ps) == _snapshot(pv), f"diverged at step {step}"
        t += 1.0
    assert ps.energy_j > 0


def test_vector_pool_rejects_scalar_thermal_model():
    from repro.power.thermal import ThermalModel
    spec = tiny_spec()
    with pytest.raises(TypeError):
        make_unit_pool(spec, backend="vector",
                       opp_table=sd865_opp_table(),
                       thermal=ThermalModel(spec))
    with pytest.raises(ValueError):
        make_unit_pool(spec, backend="neon")


# ---------------------------------------------------------------------------
# QueueWorkload.step_fast is pinned to step().
# ---------------------------------------------------------------------------
def test_step_fast_matches_step():
    rng = np.random.default_rng(7)
    a, b = QueueWorkload(unit_rate=3.0), QueueWorkload(unit_rate=3.0)
    t = 0.0
    for _ in range(200):
        if rng.random() < 0.7:
            cost = float(rng.random() * 10)
            a.submit(Request(cost=cost, arrival_s=t))
            b.submit(Request(cost=cost, arrival_s=t))
        n = int(rng.integers(0, 4))
        s = a.step(n, 1.0, t)
        used, util, queued, touched = b.step_fast(n, 1.0, t)
        assert (s.work_done, s.utilization, s.queued, s.concurrency) \
            == (used, util, queued, touched)
        ra, rb = a.drain(), b.drain()
        assert [(r.rid, r.arrival_s, r.finish_s) for r in ra] \
            == [(r.rid, r.arrival_s, r.finish_s) for r in rb]
        t += 1.0
