"""Scalar <-> vector backend parity: the vectorized pool and fleet
engines must reproduce the scalar reference **bitwise** — energy
integrals (fig7/fig14), latency percentiles, and temperature/throttle/
fan histograms (fig15/fig16) — across every simulation path: plain
gating, multi-tenant arbitration, straggler hedging, DVFS governors,
and thermal throttling, at both rack and fleet scale."""
import numpy as np
import pytest

from repro.core.cluster import (ClusterSpec, UnitSpec, edge_server_gpu,
                                soc_cluster)
from repro.core.scheduler import diurnal_trace
from repro.fleet import Fleet, RackConfig, RoundRobinRouter, homogeneous_fleet
from repro.power import (FixedFreqGovernor, RaceToIdleGovernor,
                         SchedutilGovernor, ThermalAwareGovernor,
                         ThermalParams, opp_table_for_unit, sd865_opp_table)
from repro.runtime import (ClusterRuntime, MultiTenantRuntime, QueueWorkload,
                           Request, ScalePolicy, Tenant, UnitPool,
                           VectorUnitPool, make_unit_pool)

BACKENDS = ("scalar", "vector")


def tiny_spec(n=6, group=3):
    return ClusterSpec(
        name="tiny", n_units=n, p_shared=10.0, group_size=group,
        unit=UnitSpec("u", p_off=0.0, p_idle=0.5, p_peak=4.0, gamma=1.0))


def assert_telemetry_equal(a, b, thermal=False):
    """Bitwise comparison of every fig7/fig14/fig15-relevant field."""
    assert np.array_equal(a.time_s, b.time_s)
    assert np.array_equal(a.power_w, b.power_w)
    assert np.array_equal(a.active_units, b.active_units)
    assert np.array_equal(a.utilization, b.utilization)
    assert np.array_equal(a.offered_load, b.offered_load)
    assert a.energy_j == b.energy_j                    # energy integral
    assert a.unit_energy_j == b.unit_energy_j
    assert a.served == b.served
    assert a.hedged == b.hedged
    assert a.scale_events == b.scale_events
    assert a.p50_latency_s == b.p50_latency_s
    assert a.p99_latency_s == b.p99_latency_s
    la = sorted(r.latency_s for r in a.responses)
    lb = sorted(r.latency_s for r in b.responses)
    assert la == lb


def assert_pool_hists_equal(pa, pb):
    assert pa.power_hist == [float(x) for x in pb.power_hist]
    assert pa.max_temp_hist == [float(x) for x in pb.max_temp_hist]
    assert pa.throttled_hist == [int(x) for x in pb.throttled_hist]
    assert pa.fan_power_hist == [float(x) for x in pb.fan_power_hist]


# ---------------------------------------------------------------------------
# fig7-style: single tenant, binary gating, diurnal energy integral.
# ---------------------------------------------------------------------------
def test_single_tenant_diurnal_bitwise():
    def run(backend):
        rt = ClusterRuntime(
            soc_cluster(), QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(cooldown_s=120.0), dt_s=60.0,
            backend=backend)
        trace = diurnal_trace(peak_rps=550.0, hours=4, dt_s=60.0, seed=0)
        return rt.play_trace(trace, dt_s=60.0)

    assert_telemetry_equal(run("scalar"), run("vector"))


# ---------------------------------------------------------------------------
# fig14-style: three tenants, anti-phase diurnal, hedging enabled.
# ---------------------------------------------------------------------------
def _mixed_run(backend):
    spec = soc_cluster()
    wls = {m: QueueWorkload(unit_rate=r, name=m)
           for m, r in (("transcode", 16.0), ("dl", 30.0), ("lm", 8.0))}
    rt = MultiTenantRuntime(
        spec,
        [Tenant(m, wl, policy=ScalePolicy(cooldown_s=120.0, min_units=2,
                                          hedge_after_s=240.0))
         for m, wl in wls.items()],
        dt_s=60.0, backend=backend)
    n = int(4 * 3600 / 60)
    traces = {}
    for i, (m, wl) in enumerate(wls.items()):
        tr = diurnal_trace(peak_rps=wl.unit_rate * spec.n_units * 0.45,
                           hours=4, dt_s=60.0, seed=i)
        traces[m] = np.roll(tr, i * n // 3)
    return rt.play_traces(traces, dt_s=60.0)


def test_multi_tenant_bitwise():
    ts, tv = _mixed_run("scalar"), _mixed_run("vector")
    assert_telemetry_equal(ts, tv)
    for m in ts.per_tenant:
        assert_telemetry_equal(ts.per_tenant[m], tv.per_tenant[m])


def _hedging_run(backend):
    """A burst that outruns the governor window so backlog ages past the
    hedge deadline while free units exist: hedging must actually fire."""
    spec = tiny_spec(n=6, group=1)
    rt = ClusterRuntime(
        spec, QueueWorkload(unit_rate=2.0),
        policy=ScalePolicy(headroom=1.0, cooldown_s=1e9,
                           hedge_after_s=1.5),
        dt_s=1.0, window_s=30.0, backend=backend)
    for _ in range(5):
        rt.submit(cost=6.0, count=6.0)
        rt.tick()
    for _ in range(40):
        if rt.tick().queued == 0:
            break
    return rt.telemetry()


def test_hedging_parity_and_fires():
    ts, tv = _hedging_run("scalar"), _hedging_run("vector")
    assert ts.hedged == tv.hedged
    assert ts.hedged > 0, "scenario must exercise the hedging path"
    assert_telemetry_equal(ts, tv)


# ---------------------------------------------------------------------------
# fig15-style: DVFS governors + thermal throttling histograms.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("governor", [None, FixedFreqGovernor(),
                                      SchedutilGovernor()])
def test_dvfs_thermal_bitwise(governor):
    def run(backend):
        spec = soc_cluster()
        rt = ClusterRuntime(
            spec, QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(min_units=spec.n_units, cooldown_s=1e9,
                               freq_governor=governor),
            opp_table=sd865_opp_table(),
            # low trip point: the latch must engage within the short run
            thermal=ThermalParams(t_trip_c=70.0, t_release_c=60.0),
            dt_s=1.0, backend=backend)
        offered = 2.0 * 10.0 * spec.n_units       # sustained overload
        for _ in range(240):
            rt.submit(cost=offered, count=offered)
            rt.tick()
        return rt

    rs, rv = run("scalar"), run("vector")
    assert_pool_hists_equal(rs.pool, rv.pool)
    assert rs.pool.energy_j == rv.pool.energy_j
    if isinstance(governor, FixedFreqGovernor):
        assert max(rs.pool.throttled_hist) > 0, \
            "fixed-max under sustained overload must trip the latch"


def test_schedutil_low_load_energy_bitwise():
    def run(backend):
        rt = ClusterRuntime(
            soc_cluster(), QueueWorkload(unit_rate=10.0),
            policy=ScalePolicy(freq_governor=SchedutilGovernor()),
            opp_table=sd865_opp_table(), dt_s=1.0, backend=backend)
        trace = np.full(120, 0.3 * 10.0 * 60)
        return rt.play_trace(trace, dt_s=1.0)

    assert_telemetry_equal(run("scalar"), run("vector"))


# ---------------------------------------------------------------------------
# Randomized pool transition sequences (placement, release order, OPPs).
# ---------------------------------------------------------------------------
def _snapshot(pool):
    return (list(pool.state), list(pool.owner),
            [pool.active(m) for m in ("a", "b", "c")],
            [pool.waking(m) for m in ("a", "b", "c")],
            pool.n_allocated(), pool.energy_j, pool.tenant_energy_j)


def test_random_op_sequences_identical():
    rng = np.random.default_rng(42)
    spec = tiny_spec(n=10, group=5)
    ps = make_unit_pool(spec, backend="scalar",
                        opp_table=sd865_opp_table(), thermal=ThermalParams())
    pv = make_unit_pool(spec, backend="vector",
                        opp_table=sd865_opp_table(), thermal=ThermalParams())
    assert isinstance(ps, UnitPool) and isinstance(pv, VectorUnitPool)
    tenants = ("a", "b", "c")
    t = 0.0
    for step in range(300):
        op = rng.integers(0, 6)
        m = tenants[rng.integers(0, 3)]
        k = int(rng.integers(0, 5))
        if op == 0:
            assert ps.wake(m, k, t + 1.0) == pv.wake(m, k, t + 1.0)
        elif op == 1:
            assert ps.release(m, k) == pv.release(m, k)
        elif op == 2:
            assert ps.advance(t, 1.0) == pv.advance(t, 1.0)
        elif op == 3:
            ps.force_active(m, k)
            pv.force_active(m, k)
        elif op == 4:
            idx = int(rng.integers(0, 5))
            ps.set_opp(m, idx)
            pv.set_opp(m, idx)
        else:
            utils = {m2: float(rng.random()) for m2 in tenants}
            extra = {m: k % 3}
            rs = ps.charge(t, 1.0, utils, extra)
            rv = pv.charge(t, 1.0, utils, extra)
            assert rs[0] == rv[0] and rs[1] == rv[1] and rs[2] == rv[2]
        assert _snapshot(ps) == _snapshot(pv), f"diverged at step {step}"
        t += 1.0
    assert ps.energy_j > 0


def test_vector_pool_rejects_scalar_thermal_model():
    from repro.power.thermal import ThermalModel
    spec = tiny_spec()
    with pytest.raises(TypeError):
        make_unit_pool(spec, backend="vector",
                       opp_table=sd865_opp_table(),
                       thermal=ThermalModel(spec))
    with pytest.raises(ValueError):
        make_unit_pool(spec, backend="neon")


# ---------------------------------------------------------------------------
# QueueWorkload.step_fast is pinned to step().
# ---------------------------------------------------------------------------
def test_step_fast_matches_step():
    rng = np.random.default_rng(7)
    a, b = QueueWorkload(unit_rate=3.0), QueueWorkload(unit_rate=3.0)
    t = 0.0
    for _ in range(200):
        if rng.random() < 0.7:
            cost = float(rng.random() * 10)
            a.submit(Request(cost=cost, arrival_s=t))
            b.submit(Request(cost=cost, arrival_s=t))
        n = int(rng.integers(0, 4))
        perf = float(rng.choice([0.5, 1.0, 1.3]))
        s = a.step(n, 1.0, t, perf_scale=perf)
        used, util, queued, touched = b.step_fast(n, 1.0, t,
                                                  perf_scale=perf)
        assert (s.work_done, s.utilization, s.queued, s.concurrency) \
            == (used, util, queued, touched)
        ra, rb = a.drain(), b.drain()
        assert [(r.rid, r.arrival_s, r.finish_s) for r in ra] \
            == [(r.rid, r.arrival_s, r.finish_s) for r in rb]
        t += 1.0


# ---------------------------------------------------------------------------
# VectorUnitPool OPP edge cases.
# ---------------------------------------------------------------------------
def _dvfs_pools():
    spec = tiny_spec(n=10, group=5)
    mk = lambda b: make_unit_pool(spec, backend=b,  # noqa: E731
                                  opp_table=sd865_opp_table(),
                                  thermal=ThermalParams())
    return spec, mk("scalar"), mk("vector")


def test_all_throttled_rack_metered_at_floor_opp():
    """Every die latched: charge() must meter every active unit at the
    table's lowest OPP regardless of the requested point, identically
    in both backends."""
    spec, ps, pv = _dvfs_pools()
    table = sd865_opp_table()
    for p in (ps, pv):
        p.force_active("a", spec.n_units)
        p.set_opp("a", table.highest)
        p.thermal.throttled[:] = [True] * spec.n_units
    for p in (ps, pv):
        assert [p.effective_opp(u) for u in range(spec.n_units)] \
            == [table.lowest] * spec.n_units
        assert p.perf_scale("a") == \
            pytest.approx(table[table.lowest].perf_scale)
    assert ps.perf_scale("a") == pv.perf_scale("a")
    rs = ps.charge(0.0, 1.0, {"a": 1.0})
    rv = pv.charge(0.0, 1.0, {"a": 1.0})
    assert rs == rv
    # the floor point draws strictly less than the requested top point
    from repro.power import unit_power
    w_low = unit_power(spec.unit, 1.0, table[table.lowest])
    w_top = unit_power(spec.unit, 1.0, table[table.highest])
    assert w_low < w_top
    expected_units = spec.n_units * w_low
    assert rs[1]["a"] == expected_units


def test_release_while_waking_under_non_nominal_opp():
    """Cancelling still-waking units under a non-nominal requested OPP:
    counts, requested points, and the next charge stay in lockstep."""
    spec, ps, pv = _dvfs_pools()
    for p in (ps, pv):
        p.set_opp("a", 1)                     # non-nominal, pre-wake
        p.force_active("a", 2)
        p.wake("a", 5, ready_t=10.0)          # still waking at t=0
        assert p.waking("a") == 5 and p.active("a") == 2
        # release 3: waking units are cancelled first
        assert p.release("a", 3) == 3
        assert p.waking("a") == 2 and p.active("a") == 2
    assert list(ps._req_opp) == list(pv._req_opp)
    assert _snapshot(ps) == _snapshot(pv)
    rs = ps.charge(0.0, 1.0, {"a": 0.7})
    rv = pv.charge(0.0, 1.0, {"a": 0.7})
    assert rs == rv
    # waking units are owned but draw only the off/idle floor: tenant
    # power covers exactly the 2 active units at OPP 1
    from repro.power import unit_power
    assert rs[1]["a"] == 2 * unit_power(spec.unit, 0.7,
                                        sd865_opp_table()[1])


def test_random_opp_state_lockstep_with_forced_latches():
    """Randomized OPP churn with latches flipped by hand between ops —
    the effective-OPP fast paths must agree with the scalar reference
    even when the latch state did not come from the thermal step."""
    rng = np.random.default_rng(11)
    spec, ps, pv = _dvfs_pools()
    tenants = ("a", "b", "c")
    t = 0.0
    for step in range(250):
        op = rng.integers(0, 7)
        m = tenants[rng.integers(0, 3)]
        k = int(rng.integers(0, 5))
        if op == 0:
            assert ps.wake(m, k, t + 1.0) == pv.wake(m, k, t + 1.0)
        elif op == 1:
            assert ps.release(m, k) == pv.release(m, k)
        elif op == 2:
            assert ps.advance(t, 1.0) == pv.advance(t, 1.0)
        elif op == 3:
            ps.force_active(m, k)
            pv.force_active(m, k)
        elif op == 4:
            idx = int(rng.integers(0, 5))
            ps.set_opp(m, idx)
            pv.set_opp(m, idx)
        elif op == 5:
            lat = rng.random(spec.n_units) < 0.3
            for u in range(spec.n_units):
                ps.thermal.throttled[u] = bool(lat[u])
            pv.thermal.throttled[:] = lat
        else:
            utils = {m2: float(rng.random()) for m2 in tenants}
            extra = {m: k % 3}
            rs = ps.charge(t, 1.0, utils, extra)
            rv = pv.charge(t, 1.0, utils, extra)
            assert rs == rv
        assert [ps.perf_scale(m2) for m2 in tenants] \
            == [pv.perf_scale(m2) for m2 in tenants]
        assert _snapshot(ps) == _snapshot(pv), f"diverged at step {step}"
        t += 1.0
    assert_pool_hists_equal(ps, pv)


# ---------------------------------------------------------------------------
# fig16-style: fleet engines under DVFS / thermal / hedging.
# ---------------------------------------------------------------------------
def assert_fleet_equal(a, b, thermal=False):
    """Bitwise comparison of the fleet roll-up and per-rack series."""
    assert a.energy_j == b.energy_j
    assert np.array_equal(a.power_w, b.power_w)
    assert np.array_equal(a.active_units, b.active_units)
    assert np.array_equal(a.queued, b.queued)
    assert a.served == b.served
    assert (a.p50_latency_s, a.p95_latency_s, a.p99_latency_s) \
        == (b.p50_latency_s, b.p95_latency_s, b.p99_latency_s)
    for ra, rb in zip(a.per_rack, b.per_rack):
        assert ra.energy_j == rb.energy_j
        assert ra.unit_energy_j == rb.unit_energy_j
        assert ra.hedged == rb.hedged
        assert ra.scale_events == rb.scale_events
        assert np.array_equal(ra.utilization, rb.utilization)
        assert np.array_equal(ra.max_temp_c, rb.max_temp_c)
        assert np.array_equal(ra.throttled_units, rb.throttled_units)
        assert np.array_equal(ra.fan_power_w, rb.fan_power_w)
        if thermal:
            assert len(ra.max_temp_c), "thermal series must be recorded"


def _fleet_run(backend, racks, trace, dt_s=60.0):
    return Fleet(racks, router=RoundRobinRouter(), dt_s=dt_s,
                 backend=backend).play_trace(trace)


def test_fleet_schedutil_bitwise():
    def racks():
        return homogeneous_fleet(
            soc_cluster(), 4, 30.0,
            policy=ScalePolicy(cooldown_s=300.0,
                               freq_governor=SchedutilGovernor()),
            opp_table=sd865_opp_table())

    trace = diurnal_trace(peak_rps=3000.0, hours=3, dt_s=60.0, seed=3)
    a = _fleet_run("scalar", racks(), trace)
    b = _fleet_run("vector", racks(), trace)
    assert_fleet_equal(a, b)


def test_fleet_thermal_throttle_bitwise_and_fires():
    """fig15-style sustained overload on pinned-max racks: the trip
    latch must fire, and a mixed-in GPU rack (gamma != 1, generic OPP
    ladder, race-to-idle governor) must match too."""
    def racks():
        rs = homogeneous_fleet(
            soc_cluster(), 3, 30.0,
            policy=ScalePolicy(min_units=60, cooldown_s=1e9,
                               freq_governor=FixedFreqGovernor()),
            opp_table=sd865_opp_table(),
            thermal=ThermalParams(t_trip_c=70.0, t_release_c=60.0))
        gpu = edge_server_gpu()
        rs.append(RackConfig(
            gpu, 20.0,
            policy=ScalePolicy(freq_governor=RaceToIdleGovernor()),
            opp_table=opp_table_for_unit(gpu.unit)))
        return rs

    trace = np.full(40, 9000.0)
    a = _fleet_run("scalar", racks(), trace)
    b = _fleet_run("vector", racks(), trace)
    assert_fleet_equal(a, b, thermal=False)
    assert sum(t.throttled_units.sum() for t in b.per_rack
               if len(t.throttled_units)) > 0, \
        "scenario must exercise the trip latch"


def test_fleet_thermal_aware_clamp_bitwise():
    def racks():
        return homogeneous_fleet(
            soc_cluster(), 3, 30.0,
            policy=ScalePolicy(
                hedge_after_s=120.0,
                freq_governor=ThermalAwareGovernor(SchedutilGovernor())),
            opp_table=sd865_opp_table(), thermal=ThermalParams())

    trace = diurnal_trace(peak_rps=2500.0, hours=2, dt_s=60.0, seed=5)
    a = _fleet_run("scalar", racks(), trace)
    b = _fleet_run("vector", racks(), trace)
    assert_fleet_equal(a, b, thermal=True)
    # the clamp holds every rack at or below the sustainable ceiling —
    # nothing may ever latch
    assert all(t.throttled_units.max() == 0 for t in b.per_rack)


@pytest.mark.parametrize("dvfs", [False, True])
def test_fleet_hedging_lockstep(dvfs):
    """An overload burst then silence: the governor scales down, free
    units appear while the backlog is old, and hedging must fire the
    same number of times — with bitwise-equal energy — on both
    engines."""
    def racks():
        gov = SchedutilGovernor() if dvfs else None
        tbl = sd865_opp_table() if dvfs else None
        return [RackConfig(
            tiny_spec(n=6, group=3), 2.0,
            policy=ScalePolicy(headroom=1.0, cooldown_s=0.0,
                               hedge_after_s=1.5, freq_governor=gov),
            opp_table=tbl) for _ in range(3)]

    trace = [108.0] * 3 + [0.0] * 60
    a = _fleet_run("scalar", racks(), trace, dt_s=1.0)
    b = _fleet_run("vector", racks(), trace, dt_s=1.0)
    assert_fleet_equal(a, b)
    hedged = sum(t.hedged for t in b.per_rack)
    assert hedged > 0, "scenario must exercise the hedging path"


def test_fleet_thermal_collapse_with_hedging_bitwise():
    """The hardest composite: a power-aware router overdrives its
    favourite racks, schedutil is forced to the top OPP, trip latches
    collapse throughput, and hedging fires on the backlog — throttling
    and hedging active in the same ticks. Caught a real one-ulp
    divergence once: float ``np.add.reduceat`` group sums are not
    left-to-right, unlike the scalar accumulation loop (the engines now
    use weighted ``bincount``)."""
    from repro.fleet import PowerAwareRouter, scale_to_users

    def racks():
        return homogeneous_fleet(
            soc_cluster(), 6, 30.0,
            policy=ScalePolicy(freq_governor=SchedutilGovernor(),
                               hedge_after_s=300.0),
            opp_table=sd865_opp_table(), thermal=ThermalParams())

    trace = scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=3, dt_s=60.0),
        users=2.4e5, rps_per_user=0.02)
    a = Fleet(racks(), router=PowerAwareRouter(), dt_s=60.0,
              backend="scalar").play_trace(trace)
    b = Fleet(racks(), router=PowerAwareRouter(), dt_s=60.0,
              backend="vector").play_trace(trace)
    assert_fleet_equal(a, b)
    assert sum(t.throttled_units.sum() for t in b.per_rack) > 0, \
        "scenario must exercise the trip latch"
    assert sum(t.hedged for t in b.per_rack) > 0, \
        "scenario must exercise hedging under throttling"


def test_fleet_generic_governor_fallback_bitwise():
    """A governor outside the built-in set takes the per-rack
    FreqContext fallback path and still matches the scalar engine."""
    class EveryOther(SchedutilGovernor):
        """Subclass: deliberately NOT recognized by the stacked pass."""
        def select(self, ctx):
            return ctx.table.lowest if int(ctx.demand_rate) % 2 \
                else ctx.table.highest

    def racks():
        return homogeneous_fleet(
            soc_cluster(), 2, 30.0,
            policy=ScalePolicy(freq_governor=EveryOther()),
            opp_table=sd865_opp_table())

    trace = diurnal_trace(peak_rps=2000.0, hours=1, dt_s=60.0, seed=9)
    a = _fleet_run("scalar", racks(), trace)
    b = _fleet_run("vector", racks(), trace)
    assert_fleet_equal(a, b)
