"""Multi-device behaviours via subprocesses with fake XLA host devices:
ring collective-matmuls, compressed all-reduce, pipeline parallelism, and
a small sharded train step."""
import pytest

pytestmark = pytest.mark.multidevice


@pytest.fixture(autouse=True)
def _need_devices(require_fake_devices):
    """All tests here spawn subprocesses with fake XLA host devices; skip
    the module on hosts where that capability is missing."""


def test_ring_collective_matmuls(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np, functools
from jax.sharding import PartitionSpec as P
from repro.distributed.compat import shard_map
from repro.distributed.collectives import (ring_ag_matmul, ring_matmul_rs,
                                           naive_ag_matmul, naive_matmul_rs)
mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
ref = x @ w
ag = jax.jit(shard_map(functools.partial(ring_ag_matmul, axis_name="model"),
    mesh=mesh, in_specs=(P(None, "model"), P(None, "model")),
    out_specs=P(None, "model")))(x, w)
assert float(jnp.max(jnp.abs(ag - ref))) < 1e-4, "ag"
rs = jax.jit(shard_map(functools.partial(ring_matmul_rs, axis_name="model"),
    mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
    out_specs=P(None, "model")))(x, w)
assert float(jnp.max(jnp.abs(rs - ref))) < 1e-4, "rs"
print("OK")
"""
    r = subproc(code, devices=8)
    assert "OK" in r.stdout, r.stderr


def test_compressed_allreduce(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np, functools
from jax.sharding import PartitionSpec as P
from repro.distributed.compat import shard_map
from repro.distributed.compression import compressed_psum_mean, wire_bytes_fp32, wire_bytes_compressed
mesh = jax.make_mesh((8,), ("d",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)
fn = jax.jit(shard_map(functools.partial(compressed_psum_mean, axis_name="d"),
    mesh=mesh, in_specs=(P("d"),), out_specs=P("d")))
out = fn(g)
ref = jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape)
rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
assert rel < 0.05, rel
assert wire_bytes_compressed(1<<20, 8) < 0.3 * wire_bytes_fp32(1<<20, 8)
print("OK", rel)
"""
    r = subproc(code, devices=8)
    assert "OK" in r.stdout, r.stderr


def test_pipeline_parallel_forward(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import make_pipelined_fn
mesh = jax.make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])
stacked = {"w": jnp.asarray(rng.standard_normal((4, 16, 16)), jnp.float32) * 0.5}
x_mb = jnp.asarray(rng.standard_normal((8, 4, 16)), jnp.float32)
out = jax.jit(make_pipelined_fn(stage_fn, mesh, 4))(stacked, x_mb)
ref = x_mb
for s in range(4):
    ref = jnp.tanh(ref @ stacked["w"][s])
assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
print("OK")
"""
    r = subproc(code, devices=4)
    assert "OK" in r.stdout, r.stderr


def test_sharded_train_step_runs(subproc):
    """End-to-end: sharded train step on a 2x2 mesh (DPxTP) must run and
    produce finite loss, with params actually sharded."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.config import get_config, smoke_config, TrainConfig
from repro.distributed.sharding import train_rules, use_sharding
from repro.launch.mesh import make_mesh
from repro.models import model as lm
from repro.training.optimizer import init_opt_state
from repro.training.train_loop import jit_train_step
cfg = smoke_config(get_config("internlm2-1.8b"))
tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=4, remat="none")
mesh = make_mesh((2, 2), ("data", "model"))
rules = train_rules()
step = jit_train_step(cfg, tcfg, mesh, rules, donate=False)
params = lm.init_params(cfg, jax.random.key(0))
opt = init_opt_state(params, tcfg)
batch = {"tokens": jnp.ones((4, 32), jnp.int32),
         "labels": jnp.ones((4, 32), jnp.int32),
         "mask": jnp.ones((4, 32), jnp.float32)}
p, o, m = step(params, opt, batch)
assert np.isfinite(float(m["loss"]))
p2, o2, m2 = step(p, o, batch)
assert float(m2["loss"]) < float(m["loss"])
print("OK", float(m["loss"]), float(m2["loss"]))
"""
    r = subproc(code, devices=4)
    assert "OK" in r.stdout, r.stderr


def test_collaborative_tp_block(subproc):
    """The paper's SS5.3 TP block: overlapped == unoverlapped == local."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.collaborative import make_tp_block
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32) * 0.1
w2 = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32) * 0.1
ref = jnp.maximum(x @ w1, 0) @ w2
for overlap in (False, True):
    fn = make_tp_block(mesh, 32, 64, overlap=overlap)
    out = fn(x, w1, w2)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, (overlap, err)
print("OK")
"""
    r = subproc(code, devices=4)
    assert "OK" in r.stdout, r.stderr
