"""Vector <-> jax backend parity: the tolerance contract.

The jax fleet engine (``Fleet(backend="jax")``) compiles the whole
per-tick pipeline with XLA, which fuses and reorders floating-point
reductions — so unlike the scalar/vector pair (bitwise, see
``tests/test_vector_parity.py``) its parity contract is
**tolerance-based**. The per-series budgets in ``RTOL`` below document
the worst relative error observed across the full scenario matrix
(binary gating / schedutil DVFS / race-to-idle + fixed mix /
thermal-aware clamp / straggler hedging, each under all three routers)
with roughly three decades of headroom; integer-valued series
(active units, queue occupancy in requests at these loads, hedge and
scale-event counters) must stay exact because every engine accumulates
them in exact arithmetic.

The jit-determinism tests pin the *other* half of the contract: one
compiled program must be bitwise reproducible run-to-run, so sweeps
are comparable across repeats even though they are only
tolerance-comparable across engines.
"""
import numpy as np
import pytest

from repro.core.cluster import soc_cluster
from repro.fleet import (Fleet, JoinShortestQueueRouter, PowerAwareRouter,
                         RackConfig, RoundRobinRouter, SweepConfig,
                         diurnal_trace, homogeneous_fleet, sweep)
from repro.power import (FixedFreqGovernor, RaceToIdleGovernor,
                         SchedutilGovernor, ThermalAwareGovernor,
                         ThermalParams, sd865_opp_table)
from repro.runtime import ScalePolicy

jax = pytest.importorskip("jax")

UNIT_RATE = 30.0   # req/s per SD865 unit (fig16 convention)
DT_S = 60.0

# Per-series relative-error budget vs the vector oracle. Observed
# worst case over the scenario matrix (2h diurnal + fig16-scale runs):
#   served     5.8e-16   energy_j   2.0e-16   power_w   7.1e-13
#   p50        1.0e-13   p99        2.6e-12   queued    0 (exact)
RTOL = {
    "served": 1e-12,
    "energy_j": 1e-12,
    "power_w": 1e-9,
    "queued": 1e-9,
    "p50_latency_s": 1e-9,
    "p95_latency_s": 1e-9,
    "p99_latency_s": 1e-9,
}
ATOL = 1e-9  # forgiveness for exact zeros (idle power, empty queues)

ROUTERS = (RoundRobinRouter, JoinShortestQueueRouter, PowerAwareRouter)


def _racks(n=4, governor=None, thermal=None, headroom=1.25, hedge=None):
    policy = ScalePolicy(cooldown_s=300.0, min_units=1,
                         headroom=headroom, hedge_after_s=hedge,
                         freq_governor=governor)
    return homogeneous_fleet(
        soc_cluster(), n, UNIT_RATE, policy=policy,
        opp_table=sd865_opp_table() if governor is not None else None,
        thermal=thermal)


def _mixed_governor_racks():
    """Half race-to-idle, half pinned-frequency racks in one fleet."""
    table = sd865_opp_table()
    racks = []
    for i, gov in enumerate([RaceToIdleGovernor(), RaceToIdleGovernor(),
                             FixedFreqGovernor(), FixedFreqGovernor()]):
        policy = ScalePolicy(cooldown_s=300.0, min_units=1,
                             freq_governor=gov)
        racks.append(RackConfig(soc_cluster(), UNIT_RATE, policy,
                                name=f"mix/{i}", opp_table=table,
                                thermal=ThermalParams()))
    return racks


SCENARIOS = {
    "binary": lambda: _racks(),
    "schedutil": lambda: _racks(governor=SchedutilGovernor(),
                                thermal=ThermalParams()),
    "race+fixed": _mixed_governor_racks,
    # tight trip/release window so the clamp actually engages
    "thermal-clamp": lambda: _racks(
        governor=ThermalAwareGovernor(SchedutilGovernor()),
        thermal=ThermalParams(t_trip_c=70.0, t_release_c=60.0)),
    # under-provisioned so backlog ages past the hedge deadline
    "hedging": lambda: _racks(headroom=0.8, hedge=120.0),
}
# fraction of fleet capacity at the diurnal peak; >1 for the hedging
# scenario so queues build and hedges actually fire
LOAD_FRAC = {"hedging": 0.95}


def _trace(racks, name, hours=2, seed=3):
    cap = sum(rc.spec.n_units * rc.unit_rate for rc in racks)
    frac = LOAD_FRAC.get(name, 0.55)
    return frac * cap * diurnal_trace(peak_rps=1.0, hours=hours,
                                      dt_s=DT_S, seed=seed)


def _play(name, router_cls, backend):
    racks = SCENARIOS[name]()
    fleet = Fleet(racks, router=router_cls(), dt_s=DT_S, backend=backend)
    return fleet.play_trace(_trace(racks, name))


def assert_tolerance_parity(tv, tj):
    """tv = vector oracle, tj = jax run of the same scenario."""
    assert tv.ticks == tj.ticks
    assert tv.drained == tj.drained
    # integer-valued outputs: exact in any accumulation order
    assert np.array_equal(tv.active_units, tj.active_units)
    assert [r.hedged for r in tv.per_rack] == \
        [r.hedged for r in tj.per_rack]
    assert [r.scale_events for r in tv.per_rack] == \
        [r.scale_events for r in tj.per_rack]
    np.testing.assert_allclose(tj.power_w, tv.power_w,
                               rtol=RTOL["power_w"], atol=ATOL)
    np.testing.assert_allclose(tj.queued, tv.queued,
                               rtol=RTOL["queued"], atol=ATOL)
    for field in ("served", "energy_j", "p50_latency_s",
                  "p95_latency_s", "p99_latency_s"):
        np.testing.assert_allclose(getattr(tj, field),
                                   getattr(tv, field),
                                   rtol=RTOL[field], atol=ATOL)


# ---------------------------------------------------------------------------
# tolerance parity: every scenario under every router
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_scenario_parity_all_routers(scenario):
    for router_cls in ROUTERS:
        tv = _play(scenario, router_cls, "vector")
        tj = _play(scenario, router_cls, "jax")
        assert_tolerance_parity(tv, tj)


def test_hedging_fires_and_counts_match():
    """The parity must be exercised, not vacuous: the under-provisioned
    scenario has to produce actual hedge firings in both engines."""
    tv = _play("hedging", JoinShortestQueueRouter, "vector")
    tj = _play("hedging", JoinShortestQueueRouter, "jax")
    hedged = sum(r.hedged for r in tv.per_rack)
    assert hedged > 0
    assert hedged == sum(r.hedged for r in tj.per_rack)


def test_thermal_clamp_engages():
    """Same non-vacuity check for the thermal scenario: the tight trip
    window must actually throttle (fewer effective req served per watt
    than the unclamped schedutil fleet would imply is fine — we only
    need the clamp path to run and still match)."""
    tv = _play("thermal-clamp", RoundRobinRouter, "vector")
    tj = _play("thermal-clamp", RoundRobinRouter, "jax")
    assert_tolerance_parity(tv, tj)
    assert tv.energy_j > 0


# ---------------------------------------------------------------------------
# jit determinism: same program, same inputs -> bitwise-equal outputs
# ---------------------------------------------------------------------------
def _sweep_once(drain_ticks=None):
    racks = _racks(n=3)
    trace = _trace(racks, "binary", hours=1, seed=5)
    configs = [
        SweepConfig(router="round-robin", name="rr"),
        SweepConfig(router="join-shortest-queue",
                    headroom_scale=1.1, name="jsq"),
        SweepConfig(router="power-aware", trace_scale=0.9, name="pa"),
        SweepConfig(router="join-shortest-queue",
                    hedge_after_s=120.0, name="jsq-hedge"),
    ]
    return sweep(racks, configs, trace, dt_s=DT_S,
                 drain_ticks=drain_ticks)


def test_sweep_jit_determinism():
    """Two invocations of the same jitted sweep are **bitwise** equal —
    tolerance applies across engines, never across repeats."""
    rows_a = _sweep_once()
    rows_b = _sweep_once()
    assert len(rows_a) == len(rows_b) == 4
    for ra, rb in zip(rows_a, rows_b):
        assert ra.keys() == rb.keys()
        for key in ra:
            assert ra[key] == rb[key], key


def test_engine_jit_determinism():
    ta = _play("schedutil", JoinShortestQueueRouter, "jax")
    tb = _play("schedutil", JoinShortestQueueRouter, "jax")
    assert np.array_equal(ta.power_w, tb.power_w)
    assert np.array_equal(ta.queued, tb.queued)
    assert ta.energy_j == tb.energy_j
    assert ta.p99_latency_s == tb.p99_latency_s


# ---------------------------------------------------------------------------
# sweep vs dedicated engine runs
# ---------------------------------------------------------------------------
def test_sweep_matches_dedicated_runs():
    """Each sweep row must match a per-config ``Fleet(backend="jax")``
    run, given a drain budget large enough for every config."""
    racks = _racks(n=3)
    trace = _trace(racks, "binary", hours=1, seed=5)
    router_cls = {"round-robin": RoundRobinRouter,
                  "join-shortest-queue": JoinShortestQueueRouter,
                  "power-aware": PowerAwareRouter}
    rows = sweep(racks, [SweepConfig(router=r) for r in router_cls],
                 trace, dt_s=DT_S, drain_ticks=600)
    for row, (rname, cls) in zip(rows, router_cls.items()):
        tel = Fleet(_racks(n=3), router=cls(), dt_s=DT_S,
                    backend="jax").play_trace(trace)
        assert row["router"] == rname
        assert row["ticks"] == tel.ticks
        assert bool(row["drained"]) == tel.drained
        np.testing.assert_allclose(row["served"], tel.served,
                                   rtol=1e-12, atol=ATOL)
        np.testing.assert_allclose(row["energy_j"], tel.energy_j,
                                   rtol=1e-12, atol=ATOL)
        np.testing.assert_allclose(row["p95_latency_s"],
                                   tel.p95_latency_s,
                                   rtol=1e-9, atol=ATOL)


@pytest.mark.multidevice
def test_sweep_shards_across_devices():
    """With >1 host device (``XLA_FLAGS=--xla_force_host_platform_
    device_count=N``, as the CI jax-backend job sets) the sweep pmaps
    chunks across devices; results must not depend on the sharding."""
    if jax.local_device_count() < 2:
        pytest.skip("needs >1 XLA host device")
    rows = _sweep_once(drain_ticks=600)
    racks = _racks(n=3)
    trace = _trace(racks, "binary", hours=1, seed=5)
    tel = Fleet(racks, router=RoundRobinRouter(), dt_s=DT_S,
                backend="jax").play_trace(trace)
    np.testing.assert_allclose(rows[0]["energy_j"], tel.energy_j,
                               rtol=1e-12, atol=ATOL)
    np.testing.assert_allclose(rows[0]["served"], tel.served,
                               rtol=1e-12, atol=ATOL)


# ---------------------------------------------------------------------------
# backend validation
# ---------------------------------------------------------------------------
def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="'scalar', 'vector', or 'jax'"):
        Fleet(_racks(n=2), backend="cuda")


def test_generic_governor_rejected():
    class WeirdGovernor:
        def select(self, ctx):
            return 0

    policy = ScalePolicy(freq_governor=WeirdGovernor())
    racks = homogeneous_fleet(soc_cluster(), 2, UNIT_RATE, policy=policy,
                              opp_table=sd865_opp_table())
    with pytest.raises(ValueError, match="generic governors"):
        Fleet(racks, backend="jax")
    # the vector engine stays the escape hatch the error points at
    Fleet(racks, backend="vector")


def test_custom_router_rejected():
    class MyRouter:
        name = "my-router"

        def route(self, total_rps, view):
            return np.full(view.n_racks, total_rps / view.n_racks)

    with pytest.raises(ValueError, match="custom routers"):
        Fleet(_racks(n=2), router=MyRouter(), backend="jax")


def test_unknown_sweep_router_rejected():
    racks = _racks(n=2)
    trace = _trace(racks, "binary", hours=1)
    with pytest.raises(ValueError, match="unknown sweep router"):
        sweep(racks, [SweepConfig(router="least-loaded")], trace)
