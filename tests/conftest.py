import functools
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600
                      ) -> subprocess.CompletedProcess:
    """Run `code` in a fresh python with N fake XLA host devices.

    Multi-device behaviours (shard_map collectives, pipelines, meshes)
    can't run in the main pytest process, which is pinned to 1 device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@functools.lru_cache(maxsize=None)
def fake_devices_available(n: int = 8) -> bool:
    """Whether a subprocess can actually get `n` fake XLA host devices
    (some platforms ignore --xla_force_host_platform_device_count)."""
    r = run_in_subprocess(
        f"import jax; assert jax.device_count() >= {n}", devices=n,
        timeout=300)
    return r.returncode == 0


@pytest.fixture(scope="session")
def require_fake_devices():
    """Skip (not fail) multi-device tests on hosts that can't provide
    enough devices."""
    if not fake_devices_available(8):
        pytest.skip("insufficient jax devices (fake host devices "
                    "unavailable); multidevice tests need >= 8")


@pytest.fixture
def subproc():
    return run_in_subprocess


@pytest.fixture
def rng():
    return np.random.default_rng(0)
