import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600
                      ) -> subprocess.CompletedProcess:
    """Run `code` in a fresh python with N fake XLA host devices.

    Multi-device behaviours (shard_map collectives, pipelines, meshes)
    can't run in the main pytest process, which is pinned to 1 device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture
def subproc():
    return run_in_subprocess


@pytest.fixture
def rng():
    return np.random.default_rng(0)
