"""Chaos engineering: correlated fault injection across all three
fleet engines (``repro.fleet.chaos``).

Covers the parity contract (scalar/vector bitwise under chaos, jax on
the tolerance budgets from ``tests/test_jax_parity.py``), the
drop/respill queue policy on full-rack kills, router degradation,
recovery metrics, the sim-clocked :class:`ChaosMonitor`, sanitizer
resurrection trapping, chaos trace instants, and SLO alert coverage
during fault windows.

The randomized tests derive their schedule from ``chaos_seed()``
(``REPRO_CHAOS_SEED`` env var — CI sets it from ``github.run_id`` and
echoes the repro command). The long soak is gated behind
``REPRO_CHAOS_SOAK=1`` (nightly CI only).
"""
import math
import os
import time

import numpy as np
import pytest

from repro.core.cluster import soc_cluster
from repro.fleet import (ChaosEvent, ChaosMonitor, ChaosSchedule, Fleet,
                         JoinShortestQueueRouter, PowerAwareRouter,
                         RoundRobinRouter, chaos_seed, diurnal_trace,
                         flash_crowd_trace, hedging_delta,
                         homogeneous_fleet)
from repro.obs import FleetObs, QueueBlowupRule, SloPolicy
from repro.obs.trace import build_chrome_trace, validate_chrome_trace
from repro.power import SchedutilGovernor, ThermalParams, sd865_opp_table
from repro.runtime import ScalePolicy
from repro.runtime.sanitize import InvariantViolation

UNIT_RATE = 30.0
DT_S = 60.0
HOUR = 3600.0

# jax tolerance budgets (same contract as tests/test_jax_parity.py)
RTOL = {"served": 1e-12, "energy": 1e-12, "power": 1e-9, "queued": 1e-9,
        "lat": 1e-9}
ATOL = 1e-9


def _racks(n=4, governor=False, thermal=None, hedge=None):
    policy = ScalePolicy(
        cooldown_s=300.0, min_units=1, headroom=1.25,
        hedge_after_s=hedge,
        freq_governor=SchedutilGovernor() if governor else None)
    return homogeneous_fleet(
        soc_cluster(), n, UNIT_RATE, policy=policy,
        opp_table=sd865_opp_table() if governor else None,
        thermal=thermal)


def _full_schedule(on_kill="respill"):
    """All four fault kinds: rack kill, partial kill, fan rail, power
    cap — the correlated-failure set the module exists for."""
    sched = ChaosSchedule(on_kill=on_kill)
    sched.kill_rack(1, start_s=4 * HOUR, end_s=8 * HOUR)
    sched.kill_units(2, 20, start_s=5 * HOUR, end_s=9 * HOUR)
    sched.fail_fan(0, start_s=3 * HOUR, end_s=10 * HOUR)
    sched.power_cap(3, start_s=6 * HOUR, end_s=11 * HOUR)
    return sched


def _fleet(backend, sched, *, n=4, dt_s=DT_S, router=None, thermal=None,
           hedge=None, governor=True, obs=None):
    return Fleet(_racks(n, governor=governor, thermal=thermal, hedge=hedge),
                 router=router or JoinShortestQueueRouter(), dt_s=dt_s,
                 backend=backend, chaos=sched, sanitize=True, obs=obs)


def _backlog_trace(n=4, dt_s=DT_S, ticks=80):
    """Flash crowd holding through a kill window so the dead rack has a
    deep queue when the kill lands (non-vacuous drop/respill)."""
    cap = n * 60 * UNIT_RATE
    return flash_crowd_trace(
        base_rps=0.35 * cap, spike_mult=4.0, hours=ticks * dt_s / HOUR,
        dt_s=dt_s, spike_start_h=0.25 * ticks * dt_s / HOUR,
        spike_ramp_h=0.05 * ticks * dt_s / HOUR,
        spike_hold_h=0.6 * ticks * dt_s / HOUR, seed=3)


def _backlog_schedule(on_kill, dt_s=DT_S):
    sched = ChaosSchedule(on_kill=on_kill)
    sched.kill_rack(1, start_s=30 * dt_s, end_s=60 * dt_s)
    return sched


# ---------------------------------------------------------------------------
# Scalar/vector bitwise parity under chaos.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("on_kill", ["respill", "drop"])
def test_scalar_vector_bitwise_under_chaos(on_kill):
    trace = diurnal_trace(peak_rps=0.7 * 4 * 60 * UNIT_RATE, hours=16,
                          dt_s=DT_S)
    ts = _fleet("scalar", _full_schedule(on_kill), thermal=ThermalParams(),
                hedge=240.0).play_trace(trace)
    tv = _fleet("vector", _full_schedule(on_kill), thermal=ThermalParams(),
                hedge=240.0).play_trace(trace)
    assert ts.served == tv.served
    assert ts.energy_j == tv.energy_j
    assert np.array_equal(ts.power_w, tv.power_w)
    assert np.array_equal(ts.queued, tv.queued)
    assert np.array_equal(ts.active_units, tv.active_units)
    assert np.array_equal(ts.assigned_rps, tv.assigned_rps)
    assert ts.p99_latency_s == tv.p99_latency_s
    assert ts.dropped_requests == tv.dropped_requests
    assert ts.respilled_requests == tv.respilled_requests
    assert ts.dropped_cost == tv.dropped_cost
    assert ts.respilled_cost == tv.respilled_cost


def test_random_schedule_scalar_vector_bitwise():
    """The randomized CI gate: the seed comes from ``REPRO_CHAOS_SEED``
    (github.run_id in CI), so a red run reproduces locally with
    ``REPRO_CHAOS_SEED=<n> pytest tests/test_chaos.py``."""
    seed = chaos_seed(default=20260808)
    horizon = 120 * DT_S
    sched = ChaosSchedule.random(4, horizon, seed=seed, n_events=4)
    trace = diurnal_trace(peak_rps=0.6 * 4 * 60 * UNIT_RATE,
                          hours=horizon / HOUR, dt_s=DT_S)
    ts = _fleet("scalar", sched, thermal=ThermalParams()).play_trace(trace)
    tv = _fleet("vector", sched, thermal=ThermalParams()).play_trace(trace)
    assert ts.served == tv.served, f"seed={seed}"
    assert ts.energy_j == tv.energy_j, f"seed={seed}"
    assert np.array_equal(ts.power_w, tv.power_w), f"seed={seed}"
    assert np.array_equal(ts.queued, tv.queued), f"seed={seed}"
    assert ts.respilled_requests == tv.respilled_requests, f"seed={seed}"
    assert ts.dropped_requests == tv.dropped_requests, f"seed={seed}"


# ---------------------------------------------------------------------------
# Jax tolerance parity under chaos.
# ---------------------------------------------------------------------------
def test_jax_tolerance_parity_under_chaos():
    pytest.importorskip("jax")
    dt = 120.0
    trace = diurnal_trace(peak_rps=0.7 * 4 * 60 * UNIT_RATE, hours=24,
                          dt_s=dt)

    def run(backend):
        return _fleet(backend, _full_schedule(), dt_s=dt,
                      thermal=ThermalParams(), hedge=240.0
                      ).play_trace(trace)

    tv, tj = run("vector"), run("jax")
    assert np.isclose(tv.served, tj.served, rtol=RTOL["served"])
    assert np.isclose(tv.energy_j, tj.energy_j, rtol=RTOL["energy"])
    assert np.allclose(tv.power_w, tj.power_w, rtol=RTOL["power"],
                       atol=ATOL)
    assert np.allclose(tv.queued, tj.queued, rtol=RTOL["queued"], atol=ATOL)
    assert np.array_equal(tv.active_units, tj.active_units)
    assert np.allclose(tv.assigned_rps, tj.assigned_rps, rtol=1e-9,
                       atol=ATOL)
    assert np.allclose(tv.offered_rps, tj.offered_rps, rtol=1e-9, atol=ATOL)
    assert np.isclose(tv.p50_latency_s, tj.p50_latency_s, rtol=RTOL["lat"])
    assert np.isclose(tv.p99_latency_s, tj.p99_latency_s, rtol=RTOL["lat"])
    assert tv.respilled_requests == tj.respilled_requests
    assert tv.dropped_requests == tj.dropped_requests
    assert np.isclose(tv.respilled_cost, tj.respilled_cost, rtol=1e-9,
                      atol=ATOL)
    rv, rj = tv.recovery, tj.recovery
    assert rv is not None and rj is not None
    assert rv.reconvergence_ticks == rj.reconvergence_ticks
    assert np.isclose(rv.p99_blowup, rj.p99_blowup, rtol=1e-9)


@pytest.mark.parametrize("on_kill", ["respill", "drop"])
def test_jax_voided_request_parity(on_kill):
    """Requests evacuated by a full-rack kill are voided identically:
    exact per-request counts and cost parity vs the vector oracle."""
    pytest.importorskip("jax")
    trace = _backlog_trace()
    tv = _fleet("vector", _backlog_schedule(on_kill)).play_trace(trace)
    tj = _fleet("jax", _backlog_schedule(on_kill)).play_trace(trace)
    assert tv.respilled_requests == tj.respilled_requests
    assert tv.dropped_requests == tj.dropped_requests
    assert np.isclose(tv.respilled_cost, tj.respilled_cost, rtol=1e-9)
    assert np.isclose(tv.dropped_cost, tj.dropped_cost, rtol=1e-9)
    assert np.isclose(tv.served, tj.served, rtol=1e-11)
    assert np.allclose(tv.queued, tj.queued, rtol=1e-9, atol=ATOL)
    voided = (tv.respilled_requests if on_kill == "respill"
              else tv.dropped_requests)
    assert voided > 0, "vacuous: no backlog on the rack at kill time"


# ---------------------------------------------------------------------------
# Drop/respill accounting (non-vacuous, engine-level).
# ---------------------------------------------------------------------------
def test_respill_reoffers_and_drop_discards():
    trace = _backlog_trace()
    t_re = _fleet("vector", _backlog_schedule("respill")).play_trace(trace)
    t_dr = _fleet("vector", _backlog_schedule("drop")).play_trace(trace)
    assert t_re.respilled_requests > 0 and t_re.respilled_cost > 0.0
    assert t_re.dropped_requests == 0 and t_re.dropped_cost == 0.0
    assert t_dr.dropped_requests > 0 and t_dr.dropped_cost > 0.0
    assert t_dr.respilled_requests == 0 and t_dr.respilled_cost == 0.0
    # respilled cost re-enters through the router as offered load
    extra = float(np.sum(t_re.offered_rps) - np.sum(t_dr.offered_rps))
    assert np.isclose(extra * DT_S, t_re.respilled_cost, rtol=1e-9)


# ---------------------------------------------------------------------------
# Router degradation: a dead rack receives exactly zero.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "router", [RoundRobinRouter, JoinShortestQueueRouter, PowerAwareRouter])
def test_routers_assign_zero_to_dead_rack(router):
    sched = ChaosSchedule()
    sched.kill_rack(1, start_s=20 * DT_S, end_s=50 * DT_S)
    trace = np.full(80, 0.5 * 4 * 60 * UNIT_RATE)
    tel = _fleet("vector", sched, router=router(),
                 governor=False).play_trace(trace)
    dead_window = tel.assigned_rps[1, 20:50]
    assert np.all(dead_window == 0.0), router.name
    # and it resumes taking load after restoration
    assert tel.assigned_rps[1, 50:80].sum() > 0.0, router.name


def test_partial_kill_caps_active_units():
    sched = ChaosSchedule()
    sched.kill_units(2, 40, start_s=10 * DT_S, end_s=30 * DT_S)
    trace = np.full(50, 0.8 * 4 * 60 * UNIT_RATE)
    tel = _fleet("vector", sched).play_trace(trace)
    assert np.all(tel.active_units[2, 10:30] <= 60 - 40)
    assert tel.active_units[2, 35:].max() > 60 - 40  # recovers


# ---------------------------------------------------------------------------
# Recovery metrics.
# ---------------------------------------------------------------------------
def test_recovery_metrics_non_vacuous():
    trace = _backlog_trace(ticks=120)
    tel = _fleet("vector", _backlog_schedule("respill")).play_trace(trace)
    rec = tel.recovery
    assert rec is not None
    assert rec.fault_t == 30 * DT_S
    assert rec.baseline_p95_s > 0.0
    assert rec.p99_blowup >= 1.0
    assert rec.reconvergence_ticks is not None
    assert rec.reconvergence_ticks >= 0
    assert rec.respilled_requests == tel.respilled_requests
    summ = tel.summary()
    assert summ["chaos_events"] == 1.0
    assert summ["recovery_p99_blowup"] == rec.p99_blowup


def test_hedging_delta_runs_both_arms():
    racks = _racks(4, governor=True, hedge=180.0)
    sched = _backlog_schedule("respill")
    trace = _backlog_trace()
    delta = hedging_delta(racks, trace, sched, dt_s=DT_S,
                          router=JoinShortestQueueRouter())
    assert set(delta) == {"recovery_p99_with_hedge_s",
                          "recovery_p99_without_hedge_s",
                          "hedging_benefit_s"}
    assert delta["recovery_p99_with_hedge_s"] > 0.0
    assert delta["recovery_p99_without_hedge_s"] > 0.0


# ---------------------------------------------------------------------------
# Seeded schedule generation / REPRO_CHAOS_SEED plumbing.
# ---------------------------------------------------------------------------
def test_random_schedule_is_seed_deterministic():
    a = ChaosSchedule.random(8, 24 * HOUR, seed=7)
    b = ChaosSchedule.random(8, 24 * HOUR, seed=7)
    c = ChaosSchedule.random(8, 24 * HOUR, seed=8)
    assert [e.to_record() for e in a.events] == \
        [e.to_record() for e in b.events]
    assert [e.to_record() for e in a.events] != \
        [e.to_record() for e in c.events]
    assert all(0.0 <= e.start_s < e.end_s <= 24 * HOUR for e in a.events)


def test_chaos_seed_reads_env(monkeypatch):
    monkeypatch.delenv("REPRO_CHAOS_SEED", raising=False)
    assert chaos_seed(default=42) == 42
    monkeypatch.setenv("REPRO_CHAOS_SEED", "12345")
    assert chaos_seed(default=42) == 12345


def test_chaos_event_validation():
    with pytest.raises(ValueError):
        ChaosEvent("meteor", 0, 0.0, 10.0)
    with pytest.raises(ValueError):
        ChaosEvent("kill", 0, 10.0, 10.0)  # empty window
    ev = ChaosEvent("kill", 1, 5.0)  # open-ended
    assert ev.active(5.0) and ev.active(1e12) and not ev.active(4.9)
    assert ev.to_record()["end_s"] == math.inf


# ---------------------------------------------------------------------------
# ChaosMonitor: failure detection on the simulation clock.
# ---------------------------------------------------------------------------
def test_chaos_monitor_is_tick_deterministic():
    """Failure detection depends only on the observed tick times, never
    on wall time (the HealthTracker wall-clock-default fix)."""
    n_units = np.full(3, 64, np.int64)
    alive = np.zeros(3, np.int64)
    dead1 = alive.copy()
    dead1[1] = 64

    def feed(mon, sleep_s):
        out = []
        for t, dead in [(0.0, alive), (60.0, dead1), (120.0, dead1),
                        (180.0, dead1), (240.0, dead1)]:
            if sleep_s:
                time.sleep(sleep_s)
            mon.observe(t, dead, n_units)
            out.append(tuple(mon.failed_racks()))
        return out

    fast = feed(ChaosMonitor(3, timeout_s=2 * 60.0), 0.0)
    slow = feed(ChaosMonitor(3, timeout_s=2 * 60.0), 0.05)
    assert fast == slow
    assert fast[-1] == (1,)  # rack 1 missed > timeout_s of sim time
    assert fast[0] == fast[1] == ()  # not before the timeout


def test_fleet_chaos_monitor_flags_killed_rack():
    sched = ChaosSchedule()
    sched.kill_rack(2, start_s=10 * DT_S)  # never restored
    trace = np.full(40, 0.4 * 4 * 60 * UNIT_RATE)
    fleet = _fleet("vector", sched)
    fleet.play_trace(trace)
    assert fleet.chaos_monitor is not None
    assert 2 in fleet.chaos_monitor.failed_racks()


# ---------------------------------------------------------------------------
# Sanitizer: deliberate corruption is trapped.
# ---------------------------------------------------------------------------
def test_sanitizer_traps_resurrection():
    """A fully-dead rack that 'serves' a request is an invariant
    violation — injected deliberately by corrupting the engine's served
    accumulator (and crediting the ledger so conservation alone cannot
    mask the resurrection check)."""
    sched = ChaosSchedule()
    sched.kill_rack(1, start_s=5 * DT_S)  # dead through end of run
    trace = np.full(20, 0.3 * 4 * 60 * UNIT_RATE)
    fleet = _fleet("vector", sched)
    fleet.play_trace(trace)
    san = fleet._sanitizer
    san.check()  # clean run passes
    fleet.engine.served_acc[1] += 1.0
    san.injected[1] += 1.0  # keep conservation satisfied
    with pytest.raises(InvariantViolation, match="resurrection"):
        san.check()


def test_sanitizer_traps_conservation_break_under_chaos():
    sched = _backlog_schedule("drop")
    fleet = _fleet("vector", sched)
    fleet.play_trace(_backlog_trace())
    san = fleet._sanitizer
    san.check()
    fleet.engine.chaos_evac_by_rack[1] += 1e6  # phantom evacuation
    with pytest.raises(InvariantViolation, match="conservation"):
        san.check()


def test_sanitized_fleet_runs_clean_under_chaos():
    # sanitize=True on every _fleet() above already arms the per-tick
    # checks; this one just makes the contract explicit end to end
    for backend in ("scalar", "vector"):
        tel = _fleet(backend, _full_schedule("drop"),
                     thermal=ThermalParams()).play_trace(
            diurnal_trace(peak_rps=0.6 * 4 * 60 * UNIT_RATE, hours=12,
                          dt_s=DT_S))
        assert tel.drained


# ---------------------------------------------------------------------------
# Observability: trace instants + SLO alerts during the fault window.
# ---------------------------------------------------------------------------
def test_chaos_events_appear_as_trace_instants():
    sched = _backlog_schedule("respill")
    sched.fail_fan(0, start_s=10 * DT_S)  # open-ended
    tel = _fleet("vector", sched,
                 thermal=ThermalParams()).play_trace(_backlog_trace())
    assert len(tel.chaos_events) == 2
    trace = build_chrome_trace(tel)
    assert validate_chrome_trace(trace) == []
    names = [ev["name"] for ev in trace["traceEvents"]]
    assert "chaos_kill" in names
    assert "chaos_kill_clear" in names  # bounded window gets a clear
    assert "chaos_fan_fail" in names
    assert "chaos_fan_fail_clear" not in names  # open-ended: no clear
    kill = next(ev for ev in trace["traceEvents"]
                if ev["name"] == "chaos_kill")
    assert kill["tid"] == 2  # rack 1's track
    assert kill["ts"] == 30 * DT_S * 1e6
    fan = next(ev for ev in trace["traceEvents"]
               if ev["name"] == "chaos_fan_fail")
    assert fan["args"]["end_s"] is None  # strict JSON, no Infinity


def test_slo_alert_fires_during_chaos_window():
    slo = SloPolicy([QueueBlowupRule(max_queued=10)])
    sched = _backlog_schedule("drop")
    tel = _fleet("vector", sched,
                 obs=FleetObs(slo=slo)).play_trace(_backlog_trace())
    assert tel.alerts, "kill-induced backlog should trip the SLO rule"
    fault_t, fault_end = 30 * DT_S, 60 * DT_S
    assert any(a.t_start < fault_end and a.t_end > fault_t
               for a in tel.alerts), "no alert overlaps the fault window"


# ---------------------------------------------------------------------------
# Nightly randomized soak (REPRO_CHAOS_SOAK=1).
# ---------------------------------------------------------------------------
@pytest.mark.skipif(os.environ.get("REPRO_CHAOS_SOAK") != "1",
                    reason="set REPRO_CHAOS_SOAK=1 (nightly CI) to run")
def test_chaos_soak_randomized():
    """Longer randomized sweep: scalar/vector bitwise + sanitizer-clean
    on a fan of seeds derived from the run seed; jax tolerance parity
    spot-checked on the first two."""
    base = chaos_seed(default=0)
    horizon = 160 * DT_S
    trace = diurnal_trace(peak_rps=0.65 * 4 * 60 * UNIT_RATE,
                          hours=horizon / HOUR, dt_s=DT_S)
    have_jax = True
    try:
        import jax  # noqa: F401
    except ImportError:
        have_jax = False
    for i in range(10):
        seed = base * 1000 + i
        on_kill = "respill" if i % 2 == 0 else "drop"
        sched = ChaosSchedule.random(4, horizon, seed=seed, n_events=5,
                                     on_kill=on_kill)
        ts = _fleet("scalar", sched,
                    thermal=ThermalParams()).play_trace(trace)
        tv = _fleet("vector", sched,
                    thermal=ThermalParams()).play_trace(trace)
        assert ts.served == tv.served, f"seed={seed}"
        assert ts.energy_j == tv.energy_j, f"seed={seed}"
        assert np.array_equal(ts.power_w, tv.power_w), f"seed={seed}"
        assert np.array_equal(ts.queued, tv.queued), f"seed={seed}"
        if have_jax and i < 2:
            tj = _fleet("jax", sched,
                        thermal=ThermalParams()).play_trace(trace)
            assert np.isclose(tv.served, tj.served,
                              rtol=RTOL["served"]), f"seed={seed}"
            assert np.allclose(tv.power_w, tj.power_w, rtol=RTOL["power"],
                               atol=ATOL), f"seed={seed}"
            assert np.allclose(tv.queued, tj.queued, rtol=RTOL["queued"],
                               atol=ATOL), f"seed={seed}"
