"""int8 matmul + rmsnorm Pallas kernels vs oracles (incl. hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels import ref
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rmsnorm import rmsnorm


@pytest.mark.parametrize("m,k,n", [(64, 128, 64), (128, 512, 256),
                                   (256, 256, 128)])
def test_int8_matmul_matches_oracle(m, k, n, rng):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    xq, sx = ref.quantize_int8(x, axis=1)
    wq, sw = ref.quantize_int8(w, axis=0)
    out_ref = ref.int8_matmul_ref(xq, sx, wq, sw)
    out = int8_matmul(xq, sx, wq, sw, block_m=64, block_n=64, block_k=128,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-6, atol=1e-4)


def test_int8_quantized_matmul_close_to_fp(rng):
    """End-to-end W8A8 vs the fp32 matmul: bounded relative error."""
    x = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    xq, sx = ref.quantize_int8(x, axis=1)
    wq, sw = ref.quantize_int8(w, axis=0)
    out = int8_matmul(xq, sx, wq, sw, interpret=True)
    ref_fp = x @ w
    rel = np.abs(np.asarray(out - ref_fp)) / (np.abs(np.asarray(ref_fp))
                                              + 1.0)
    assert rel.mean() < 0.02


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 300), d=st.sampled_from([64, 128, 256]),
       eps=st.sampled_from([1e-5, 1e-6]))
def test_rmsnorm_property(rows, d, eps):
    rng = np.random.default_rng(rows * d)
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    out = rmsnorm(x, w, eps=eps, block_rows=64, interpret=True)
    out_ref = ref.rmsnorm_ref(x, w, eps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 37, 128), (1, 1, 64), (5, 256)])
def test_rmsnorm_shapes(shape, rng):
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    w = jnp.ones((shape[-1],), jnp.float32)
    out = rmsnorm(x, w, block_rows=16, interpret=True)
    out_ref = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
    # unit-scale RMSNorm output has RMS ~= 1 per row
    rms = np.sqrt(np.mean(np.asarray(out, np.float64) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)
