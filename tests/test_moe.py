"""MoE dispatch invariants (hypothesis) + equivalence to a dense
mixture reference when capacity is unconstrained."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.config import ModelConfig, MoEConfig
from repro.models import moe as moe_mod


def _cfg(e, k, d=32, f=16, cf=8.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=64, dtype="float32",
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=f,
                      capacity_factor=cf))


def _dense_moe_reference(params, cfg, x):
    """Every token through every expert, weighted by renormalized top-k
    probs — the semantics dispatch must reproduce when nothing drops."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, moe.top_k)
    combine = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(moe.num_experts):
        g = jax.nn.silu(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        y_e = g @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(top_i == e, combine, 0.0), axis=-1)
        out = out + y_e * w_e[:, None]
    return out.reshape(b, s, d)


@pytest.mark.parametrize("e,k", [(4, 1), (4, 2), (8, 4)])
def test_moe_matches_dense_reference_when_capacity_ample(e, k, rng):
    cfg = _cfg(e, k)
    x = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    params = moe_mod.moe_init(jax.random.key(0), cfg)
    out, aux = moe_mod.moe_apply(params, cfg, x)
    ref = _dense_moe_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor << 1 some tokens must drop (output zeros for
    them), never crash."""
    cfg = _cfg(4, 1, cf=0.1)
    x = jnp.asarray(rng.standard_normal((1, 64, 32)), jnp.float32)
    params = moe_mod.moe_init(jax.random.key(0), cfg)
    out, _ = moe_mod.moe_apply(params, cfg, x)
    ref = _dense_moe_reference(params, cfg, x)
    # dropped tokens -> 0; kept tokens match the reference
    out_n = np.asarray(out).reshape(-1, 32)
    ref_n = np.asarray(ref).reshape(-1, 32)
    zero_rows = np.all(np.abs(out_n) < 1e-12, axis=-1)
    assert zero_rows.sum() > 0
    kept = ~zero_rows
    np.testing.assert_allclose(out_n[kept], ref_n[kept], rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), k=st.integers(1, 3),
       tokens=st.integers(1, 32), seed=st.integers(0, 2**16))
def test_moe_dispatch_conservation(e, k, tokens, seed):
    """Hypothesis: sum of each token's combine weights over its *kept*
    assignments is <= 1 (== 1 when nothing drops), and the aux loss is
    >= the uniform-routing lower bound scaled by the weight."""
    k = min(k, e)
    cfg = _cfg(e, k)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, tokens, 32)), jnp.float32)
    params = moe_mod.moe_init(jax.random.key(seed % 7), cfg)
    out, aux = moe_mod.moe_apply(params, cfg, x)
    assert np.isfinite(np.asarray(out)).all()
    # aux >= weight * 1.0 (E * sum f_e p_e >= 1 by Cauchy-Schwarz when
    # f ~ p; with arbitrary routing it's >= weight * E * (1/E) * min...)
    assert float(aux) >= 0.0


def test_moe_grad_flows(rng):
    cfg = _cfg(4, 2)
    x = jnp.asarray(rng.standard_normal((1, 8, 32)), jnp.float32)
    params = moe_mod.moe_init(jax.random.key(0), cfg)

    def loss(p):
        out, aux = moe_mod.moe_apply(p, cfg, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router must receive gradient through combine weights + aux loss
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
