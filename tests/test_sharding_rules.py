"""Properties of the logical-axis sharding resolver (hypothesis)."""
import jax
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (RuleSet, resolve_spec, serve_rules,
                                        train_rules)


def _mesh_1dev(shape, axes):
    # A mesh over a single device repeated is impossible; use abstract mesh
    # for resolution-only tests.
    return jax.sharding.AbstractMesh(shape, axes)


MESH = _mesh_1dev((2, 4, 8), ("pod", "data", "model"))


def test_divisibility_fallback():
    rules = RuleSet({"heads": ("model",)})
    # 10 % 8 != 0 -> replicated
    assert resolve_spec((10,), ("heads",), rules, MESH) == P()
    assert resolve_spec((16,), ("heads",), rules, MESH) == P("model")


def test_prefix_greedy_multi_axis():
    rules = RuleSet({"batch": ("pod", "data", "model")})
    # 8 = 2*4 -> uses (pod, data); model would exceed divisibility
    assert resolve_spec((8,), ("batch",), rules, MESH) == P(("pod", "data"))
    assert resolve_spec((64,), ("batch",), rules, MESH) == \
        P(("pod", "data", "model"))
    assert resolve_spec((2,), ("batch",), rules, MESH) == P("pod")


def test_no_double_use():
    rules = RuleSet({"a": ("model",), "b": ("model",)})
    spec = resolve_spec((8, 8), ("a", "b"), rules, MESH)
    assert spec == P("model")  # second dim can't reuse model


def test_unknown_logical_name_replicates():
    rules = RuleSet({})
    assert resolve_spec((128, 128), ("x", "y"), rules, MESH) == P()


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 30, 64]),
                  min_size=1, max_size=4),
    names=st.lists(st.sampled_from(["batch", "heads_act", "mlp_act",
                                    "p_embed", "kv_seq", None]),
                   min_size=1, max_size=4),
)
def test_resolver_properties(dims, names):
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    rules = train_rules()
    spec = resolve_spec(dims, names, rules, MESH)
    sizes = dict(zip(MESH.axis_names, MESH.axis_sizes))
    used = []
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            assert a not in used, "axis used twice"
            used.append(a)
            prod *= sizes[a]
        assert dims[i] % prod == 0, "non-dividing assignment"


def test_serve_rules_batch1_shards_kvseq_everywhere():
    rules = serve_rules(False, batch1=True)
    spec = resolve_spec((1, 524288, 8, 128),
                        ("batch", "kv_seq", "kv_heads_act", None),
                        rules, MESH)
    assert spec[1] == ("pod", "data", "model")
