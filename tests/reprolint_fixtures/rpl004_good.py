"""Known-good: seeded generators threaded explicitly."""
import random

import numpy as np


def jitter(rng: np.random.Generator) -> float:
    return float(rng.random())


def make_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def make_stdlib_rng(seed: int) -> random.Random:
    return random.Random(seed)


def pick(items, rng: random.Random):
    rng.shuffle(items)
    return items[0]
