# reprolint: parity-critical
"""Known-bad: pool count caches mutated outside their owning class."""


def steal_unit(pool, tid: int) -> None:
    # foreign writer corrupts the exact integer caches
    pool._n_alloc += 1
    pool._n_active_of[tid] = pool._n_active_of.get(tid, 0) + 1
    pool._free_g[0] -= 1


class Autoscaler:
    def scale_down(self, pool) -> None:
        pool._n_waking_total = 0
        pool._active_idx.pop(3)
