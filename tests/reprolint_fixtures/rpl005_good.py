# reprolint: selection
"""Known-good: selections with pinned tie-breaks."""
import numpy as np


def pick_cheapest_rack(power_w: np.ndarray) -> int:
    # composite integer key pins the tie-break to the lowest index
    order = np.argsort(power_w, kind="stable")
    return int(order[0])


def rank_racks(j_per_req: np.ndarray) -> np.ndarray:
    return np.argsort(j_per_req, kind="stable")


def better_opp(power_w: float, best_power: float) -> bool:
    # epsilon margin: a one-ulp difference cannot flip the choice
    return power_w < best_power - 1e-12
