# reprolint: parity-critical
"""Known-good: responses flow only through Workload.drain()."""


def tick(rt) -> None:
    rt.telemetry.responses.extend(rt.workload.drain())


def reset(rt) -> None:
    # resetting to empty is allowed
    rt.telemetry.responses = []


def local_buffer(workload) -> list:
    # a *local* name `responses` is not the telemetry channel
    responses = []
    responses.append("not-a-telemetry-write")
    return responses
