# reprolint: parity-critical
"""Known-good: caches mutated only inside the owning pool class, and
foreign code going through the pool's public methods."""
import numpy as np


class VectorUnitPool:
    def __init__(self, n_units: int, n_groups: int) -> None:
        self._n_alloc = 0
        self._n_waking_total = 0
        self._n_active_of = {}
        self._free_g = np.zeros(n_groups, dtype=np.int64)

    def wake(self, tid: int, k: int) -> None:
        # the owner may maintain its own caches
        self._n_alloc += k
        self._n_waking_total += k
        self._n_active_of[tid] = self._n_active_of.get(tid, 0)
        self._free_g[0] -= k


def scale_up(pool, tid: int, k: int) -> None:
    # foreign code drives the pool through its methods
    pool.wake(tid, k)
