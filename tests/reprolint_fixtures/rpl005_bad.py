# reprolint: selection
"""Known-bad: float-keyed selection without a pinned tie-break."""
import numpy as np


def pick_cheapest_rack(power_w: np.ndarray) -> int:
    # position-only tie-break: a one-ulp key change can flip the winner
    return int(np.argmin(power_w))


def rank_racks(j_per_req: np.ndarray) -> np.ndarray:
    # unstable sort over float keys
    return np.argsort(j_per_req)


def select_opp(power_w: float, best_power: float) -> bool:
    # exact float equality in a selection predicate
    return power_w == best_power


def is_tied(a: float, b: float) -> bool:
    return a / b == 1.0
