# reprolint: parity-critical
"""Known-bad: unordered float reductions RPL001 must flag.

``pr5_group_power`` reconstructs the exact shape of the PR 5 one-ulp
parity bug: per-unit power flows grouped into racks with a float
``np.add.reduceat``, whose segment-tree reduction order differs from
the scalar engine's left-to-right loop.
"""
import numpy as np


def pr5_group_power(flows: np.ndarray, group_starts: np.ndarray) -> np.ndarray:
    # the PR 5 bug: float segment sum via reduceat (order unspecified)
    return np.add.reduceat(flows, group_starts)


def total_power(per_unit_w: np.ndarray) -> float:
    return float(np.sum(per_unit_w))


def mean_latency(lat_s: np.ndarray) -> float:
    return float(lat_s.mean())


def energy_dot(power_w: np.ndarray, dt_s: np.ndarray) -> float:
    return float(np.dot(power_w, dt_s))


def method_sum(served_cost: np.ndarray) -> float:
    return float(served_cost.sum())
