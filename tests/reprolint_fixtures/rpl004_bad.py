"""Known-bad: unseeded randomness (RPL004 applies everywhere, not just
in parity-critical modules)."""
import random

import numpy as np


def jitter() -> float:
    return random.random()


def pick(items):
    random.shuffle(items)
    return items[0]


def legacy_draws(n: int) -> np.ndarray:
    return np.random.rand(n)


def unseeded_ctor():
    return np.random.default_rng()
