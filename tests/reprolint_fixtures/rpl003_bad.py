# reprolint: parity-critical
"""Known-bad: responses delivered outside the drain() channel."""


def tick(rt, fake_response) -> None:
    # second delivery path double-counts completions
    rt.telemetry.responses.append(fake_response)


def merge(rt, extra_responses) -> None:
    rt.telemetry.responses.extend(extra_responses)


def rebind(rt, stale) -> None:
    rt.telemetry.responses = stale
