# reprolint: parity-critical
"""Known-good: the order-pinned reduction idioms RPL001 allows.

``pr5_group_power_fixed`` is the shape of the actual PR 5 fix — a
weighted ``np.bincount`` group sum, which accumulates strictly in input
order, matching the scalar engine's per-unit loop bit for bit.
"""
import math

import numpy as np


def pr5_group_power_fixed(flows: np.ndarray, group_idx: np.ndarray,
                          n_groups: int) -> np.ndarray:
    # the PR 5 fix: weighted bincount adds in input order
    return np.bincount(group_idx, weights=flows, minlength=n_groups)


def total_power(per_unit_w: np.ndarray) -> float:
    # builtin sum() is strictly left-to-right
    return sum(float(w) for w in per_unit_w)


def total_power_fsum(per_unit_w: np.ndarray) -> float:
    return math.fsum(float(w) for w in per_unit_w)


def total_power_loop(per_unit_w: np.ndarray) -> float:
    acc = 0.0
    for w in per_unit_w:
        acc += float(w)
    return acc


def waived_rollup(power_w: np.ndarray) -> float:
    return float(power_w.sum())  # reprolint: ok[RPL001] roll-up-only fixture metric, not on the parity surface
