"""Known-good glob twin: same path-based scoping as the bad fixture
(the ``*repro/fleet/engine_state.py`` PARITY_CRITICAL glob, no marker
comment), but every reduction either follows the order-pinned idiom or
carries the jax tolerance-parity waiver convention, so the file must
lint clean."""
import numpy as np


def rack_energy_j(power_w: np.ndarray, dt_s: float) -> float:
    acc = 0.0
    for w in power_w:
        acc += float(w)
    return acc * dt_s


def sweep_energy_j(power_w, dt_s: float) -> float:
    import jax.numpy as jnp

    return float(jnp.sum(power_w) * dt_s)  # reprolint: ok[RPL001] jax tolerance-parity: covered by the documented energy_j rtol budget
