"""Known-bad glob fixture: this file carries NO ``# reprolint:``
marker — it is in RPL001 scope purely because its relative path
matches the ``*repro/fleet/jax_engine.py`` entry of
``tools/reprolint/config.py::PARITY_CRITICAL``. The unwaived ``jnp``
reduction below must be flagged, proving both the glob and the
jax.numpy alias coverage fire."""
import jax.numpy as jnp


def rack_energy_j(power_w, dt_s: float) -> float:
    # missing its "jax tolerance-parity" waiver: must be flagged
    return float(jnp.sum(power_w) * dt_s)
