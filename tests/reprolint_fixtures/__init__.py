# Known-bad / known-good fixture corpus for tools/reprolint.
# These modules are linted as *text* by tests/test_reprolint.py — they
# are never imported or executed, and several are deliberately wrong.
